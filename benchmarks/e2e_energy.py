"""End-to-end deployment metric: ledger-derived per-token CIM energy for
every registered architecture, per phase (prefill / decode / train).

The per-arch reports come from ``serving.engine.energy_report``, i.e. from
``core.costs`` shape-only traces of the real model functions — no analytic
MAC census. Each arch record carries, per phase, the op counts and the
pJ/token under the arch's (per-site) CIM design next to the conventional
CIM pricing of the same ops — the paper's bottom-line deployment win.

``--smoke`` writes the separate ``e2e_energy_smoke.json`` record with a
reduced Monte-Carlo sample count; the committed copy is compared by
``benchmarks/compare.py`` with **exact integer equality on the op-count
leaves** — any drift between the models and the energy accounting fails
the build (timing gates don't apply here: op counts are deterministic).

Run:  PYTHONPATH=src python -m benchmarks.e2e_energy [--smoke]
"""
import argparse

from repro.configs import get_config, list_configs
from repro.serving.engine import energy_report
from benchmarks.common import emit, save_json

# the one smoke configuration: shared by the --smoke CLI (which refreshes
# the committed e2e_energy_smoke.json) and benchmarks/compare.py's fresh
# run, so the op-count gate always compares like-for-like configs
SMOKE_PARAMS = dict(n_cols=1 << 8, prefill_bucket=64,
                    record="e2e_energy_smoke")


def run(archs=None, n_cols=1 << 11, prefill_bucket=128,
        record="e2e_energy"):
    out = {}
    for name in archs or list_configs():
        cfg = get_config(name)
        if not cfg.cim.enabled:
            cfg = cfg.replace(cim=cfg.cim.with_mode("grmac"))
        rep = energy_report(cfg, n_cols=n_cols,
                            prefill_bucket=prefill_bucket)
        out[name] = {
            "pj_per_token": rep["pj_per_token"],
            "fj_per_op": rep["fj_per_op"],
            "conventional_fj_per_op": rep["conventional_fj_per_op"],
            "phases": {
                phase: {
                    # integer op counts: the drift gate (exact compare)
                    "ops_per_token": ph["ops_per_token"],
                    "analog_ops_per_token": ph["analog_ops_per_token"],
                    "pj_per_token": ph["pj_per_token"],
                    "conventional_pj_per_token":
                        ph["conventional_pj_per_token"],
                }
                for phase, ph in rep["phases"].items()
            },
        }
        emit(f"e2e/{name}", 0.0,
             f"pj_per_token={rep['pj_per_token']:.1f}"
             f";fj_per_op={rep['fj_per_op']:.1f}")
    save_json(record, out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny Monte-Carlo + separate record for the CI "
                         "op-count drift gate")
    args = ap.parse_args()
    if args.smoke:
        run(**SMOKE_PARAMS)
    else:
        run()
