"""End-to-end deployment metric: ledger-derived per-token CIM energy for
every registered architecture, per phase (prefill / decode / train).

The per-arch reports come from ``serving.engine.energy_report``, i.e. from
``core.costs`` shape-only traces of the real model functions — no analytic
MAC census. Each arch record carries, per phase, the op counts and the
pJ/token under the arch's (per-site) CIM design next to the conventional
CIM pricing of the same ops — the paper's bottom-line deployment win.

``--pareto`` runs the per-site (format × n_r × granularity) design-space
explorer instead (``core.dse.explore_pareto``): per arch × phase it traces
the ledger, sweeps every site's candidate grid against the paper's 35 dB
accuracy standard, and records the per-site Pareto fronts, the chosen
``site_overrides`` deployment, and the deployment-level energy/accuracy
front (rendered by ``launch/summary.py --energy``).

``--smoke`` writes the separate ``*_smoke.json`` record with a reduced
Monte-Carlo sample count (and, for ``--pareto``, a reduced arch set); the
committed copies are compared by ``benchmarks/compare.py`` with **exact
equality on the op-count and frontier-membership leaves** — any drift
between the models and the energy accounting (or any silent reshuffle of
a committed Pareto front) fails the build. Timing gates don't apply here:
op counts and seeded-Monte-Carlo frontiers are deterministic.

Run:  PYTHONPATH=src python -m benchmarks.e2e_energy [--smoke] [--pareto]
"""
import argparse

from repro.configs import get_config, list_configs
from repro.core import costs, dse
from repro.serving.engine import energy_report
from benchmarks.common import emit, save_json

# the one smoke configuration: shared by the --smoke CLI (which refreshes
# the committed e2e_energy_smoke.json) and benchmarks/compare.py's fresh
# run, so the op-count gate always compares like-for-like configs
SMOKE_PARAMS = dict(n_cols=1 << 8, prefill_bucket=64,
                    record="e2e_energy_smoke")

# Pareto smoke: one reduced arch per block family (attention, MoE, SSM,
# RG-LRU) at the FULL candidate grid — the memoized solver is what keeps
# this inside the CI bench-smoke budget, and the gate proves it stays so.
PARETO_SMOKE_PARAMS = dict(
    archs=("paper-cim-120m", "grok-1-314b", "mamba2-1.3b",
           "recurrentgemma-9b"),
    n_cols=1 << 8, prefill_bucket=64, record="e2e_pareto_smoke")


def run(archs=None, n_cols=1 << 11, prefill_bucket=128,
        record="e2e_energy"):
    out = {}
    for name in archs or list_configs():
        cfg = get_config(name)
        if not cfg.cim.enabled:
            cfg = cfg.replace(cim=cfg.cim.with_mode("grmac"))
        rep = energy_report(cfg, n_cols=n_cols,
                            prefill_bucket=prefill_bucket)
        out[name] = {
            "pj_per_token": rep["pj_per_token"],
            "fj_per_op": rep["fj_per_op"],
            "conventional_fj_per_op": rep["conventional_fj_per_op"],
            "phases": {
                phase: {
                    # integer op counts: the drift gate (exact compare)
                    "ops_per_token": ph["ops_per_token"],
                    "analog_ops_per_token": ph["analog_ops_per_token"],
                    "pj_per_token": ph["pj_per_token"],
                    "conventional_pj_per_token":
                        ph["conventional_pj_per_token"],
                }
                for phase, ph in rep["phases"].items()
            },
        }
        emit(f"e2e/{name}", 0.0,
             f"pj_per_token={rep['pj_per_token']:.1f}"
             f";fj_per_op={rep['fj_per_op']:.1f}")
    save_json(record, out)
    return out


def _phase_ledgers(arch, prefill_bucket: int):
    """(ledger, tokens) per phase, mirroring ``core.costs.phase_report``'s
    trace shapes and per-token normalization."""
    train_seq = costs.default_train_seq(arch)
    return {
        "decode": (costs.trace_decode(arch), 1),
        "prefill": (costs.trace_prefill(arch, bucket=prefill_bucket),
                    prefill_bucket),
        "train": (costs.trace_train(arch, seq_len=train_seq), train_seq),
    }


def _cand_key(c: dict) -> str:
    return f"{c['fmt_x']}/n{c['n_r']}/{c['granularity']}"


def _pareto_phase_record(res: dict, tokens: int) -> dict:
    """JSON-able arch×phase cell. ``on_front`` / ``front_size`` /
    ``ops_per_token`` are the exact-compare leaves benchmarks/compare.py
    gates (frontier membership is deterministic given the seeded
    Monte-Carlo, like the trace op counts)."""
    sites = {}
    for site, info in res["sites"].items():
        if "front" not in info:     # digital site: ops only
            sites[site] = {"ops_per_token": info["ops"] / tokens,
                           "mode": "off"}
            continue
        chosen = info["chosen"]
        sites[site] = {
            "ops_per_token": info["ops"] / tokens,
            "budget_sqnr_db": info["budget_sqnr_db"],
            "base": dict(info["base"]),
            "front_size": len(info["front"]),
            "front": {
                _cand_key(c): {
                    "fj_per_op": c["fj_per_op"], "sqnr_db": c["sqnr_db"],
                    "enob": c["enob"], "on_front": 1,
                }
                for c in info["front"]
            },
            "chosen": chosen if isinstance(chosen, str)
            else _cand_key(chosen),
            "chosen_fj_per_op": None if isinstance(chosen, str)
            else chosen["fj_per_op"],
        }
    return {
        "tokens": tokens,
        "pj_per_token": res["pj"] / tokens,
        "base_pj_per_token": res["base_pj"] / tokens,
        "front_size": len(res["front"]),
        "front": {
            f"{p['sqnr_db']:.2f}dB": {
                "pj_per_token": p["pj"] / tokens, "on_front": 1,
                "choices": dict(p["choices"]),
            }
            for p in res["front"]
        },
        "site_overrides": {
            site: ov if isinstance(ov, str) else ov.as_dict()
            for site, ov in res["site_overrides"].items()
        },
        "sites": sites,
    }


def run_pareto(archs=None, n_cols=1 << 11, prefill_bucket=128,
               budget_sqnr_db=dse.PAPER_SQNR_STANDARD_DB,
               record="e2e_pareto"):
    """Per-site Pareto DSE record: arch × phase fronts + chosen designs."""
    budget = dse.SiteBudget(min_sqnr_db=budget_sqnr_db)
    out = {}
    for name in archs or list_configs():
        cfg = get_config(name)
        if not cfg.cim.enabled:
            cfg = cfg.replace(cim=cfg.cim.with_mode("grmac"))
        phases = {}
        for phase, (ledger, tokens) in \
                _phase_ledgers(cfg, prefill_bucket).items():
            res = dse.explore_pareto(cfg.cim, ledger, budget=budget,
                                     n_cols=n_cols)
            phases[phase] = _pareto_phase_record(res, tokens)
            emit(f"pareto/{name}/{phase}", 0.0,
                 f"pj_per_token={phases[phase]['pj_per_token']:.1f}"
                 f";front_size={phases[phase]['front_size']}")
        out[name] = {"budget_sqnr_db": budget.floor_db(), "phases": phases}
    save_json(record, out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny Monte-Carlo + separate record for the CI "
                         "op-count / frontier drift gate")
    ap.add_argument("--pareto", action="store_true",
                    help="run the per-site (format x n_r x granularity) "
                         "Pareto DSE instead of the energy report")
    args = ap.parse_args()
    if args.pareto:
        run_pareto(**PARETO_SMOKE_PARAMS) if args.smoke else run_pareto()
    elif args.smoke:
        run(**SMOKE_PARAMS)
    else:
        run()
