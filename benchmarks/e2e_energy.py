"""End-to-end deployment metric: per-token CIM energy for the paper's
edge config and for each assigned architecture under the GR-CIM vs the
conventional CIM design point (the paper's bottom-line deployment win)."""
from repro.configs import get_config
from repro.serving.engine import energy_report
from benchmarks.common import emit, save_json

ARCHS = ["paper-cim-120m", "qwen2-1.5b", "gemma3-1b", "mamba2-1.3b"]


def run():
    out = {}
    for name in ARCHS:
        cfg = get_config(name)
        if not cfg.cim.enabled:
            cfg = cfg.replace(cim=cfg.cim.with_mode("grmac"))
        rep = energy_report(cfg)
        out[name] = rep
        emit(f"e2e/{name}", 0.0,
             f"pj_per_token={rep['pj_per_token']:.1f};fj_per_op={rep['fj_per_op']:.1f}")
    save_json("e2e_energy", out)
    return out


if __name__ == "__main__":
    run()
