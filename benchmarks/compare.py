"""Bench-regression checker: fresh --smoke runs vs the committed records.

Runs the smoke configuration of the bench scripts (kernel_bench,
serve_bench, e2e_energy), then walks the committed ``experiments/bench/*_smoke.json``
records and compares every timing leaf against the fresh run at the same
path:

* ``warm_us`` / ``ttft_ms``  — time-like: fresh / committed > threshold
  (default 1.5x) is a regression;
* ``decode_tok_s``           — throughput-like: committed / fresh >
  threshold is a regression;
* ``ops_per_token`` / ``analog_ops_per_token`` (the e2e_energy op-count
  leaves) — **exact**: these are deterministic ledger traces of the model
  structure, so ANY drift from the committed record is a regression (the
  models changed without the committed energy record being refreshed, or
  the cost accounting broke);
* ``on_front`` / ``front_size`` (the e2e_pareto frontier-membership
  leaves) — **exact**, same contract: Pareto fronts are derived from
  seeded Monte-Carlo solves and trace op counts, so a committed front
  reshuffling silently means the design-space explorer or the energy
  model changed without the record being refreshed. A vanished front
  candidate shows up as a missing ``on_front`` leaf.
* the traffic_bench scheduling leaves (``completed`` /
  ``completed_in_slo`` / ``decode_steps`` / ``prefill_dispatches`` /
  ``queue_depth_max`` / ``goodput_tokens`` / ``knee_rate_frac`` /
  ``beats_static_above_capacity`` ...) — **exact**: the open-loop sweep
  schedules on a virtual dispatch-cost clock over seeded traffic, so
  every scheduling decision is machine-independent; drift means the
  scheduler's policy changed without the record being refreshed. Its
  wall-clock latency percentiles (``ttft_p50_ms`` / ``ttft_p99_ms`` /
  ``tpot_p50_ms`` / ``tpot_p99_ms`` / ``goodput_tok_s``) get the usual
  ratio + noise-floor gates.
* the prefix_bench leaves (shared-prefix traffic, cache-on vs
  cache-off) — cache counters (``prefix_hits`` / ``prefix_misses`` /
  ``prefix_inserts`` / ``prefix_evictions`` / ``prefix_bytes``), token
  savings (``prefill_tokens_dispatched`` / ``prefill_tokens_saved`` /
  ``recompute_tokens_saved``), admission reorders, and the derived win
  booleans (``outputs_identical`` / ``cache_wins_ttft`` /
  ``cache_wins_dispatches`` / ``prefill_pj_reduced``) — **exact**: all
  pure functions of the seeded traffic under the virtual clock, and the
  booleans are the prefix-cache tentpole's acceptance criteria. Wall
  latency percentiles get the ratio gate as in traffic_bench.
* the goodput_bench drill counters (``faults_injected`` /
  ``faults_detected`` / ``ckpt_local`` / ``ckpt_durable`` /
  ``steps_recomputed`` / ``restore_local`` / ``restore_durable`` /
  ``final_step`` / ``dp_width_final`` /
  ``trajectory_bit_identical`` ...) — **exact**: faults fire at
  scheduled steps of a deterministic loop, fleet detection runs on a
  virtual clock, and the async checkpoint writer drains at each fault
  boundary, so every counter is a pure function of (arch, plan,
  config); drift means the resilience policy changed without the record
  being refreshed. The drill's ``goodput_pct`` is wall-clock-derived
  and gets the ratio gate.
* the spec_bench leaves — self-draft speculative decode's
  ``accepted_tokens_per_step`` / ``spec_steps`` / ``spec_tokens`` /
  draft/verify/repair dispatch counts, the greedy bit-exactness
  boolean ``outputs_identical``, and the analytic pJ/accepted-token
  ``energy_win`` verdict — **exact**: self-draft greedy acceptance is
  structurally total and the energy account prices deterministic
  counters through seeded-MC ENOB pricing, so any drift means the
  draft/verify/accept policy (or the energy model) changed without the
  record being refreshed. The sequential and speculative ``ttlt_ms``
  wall times get the usual ratio + noise-floor gate.
* the ``--bench audit`` leaves (``experiments/audit/audit_report.json``,
  see ``src/repro/analysis``) — **exact**: jaxpr MAC counts, ledger
  cross-check totals, and engine compile/transfer counters are structural
  facts about the traced programs, so any drift from the committed golden
  means ledger coverage or a hot-path invariant changed without the
  golden being regenerated.

Cells faster than ``--min-us`` (default 300 us) in the committed record
are skipped: at smoke sizes those measure pure dispatch overhead and are
machine-noise, not kernel behavior. Cold times are ignored for the same
reason (compile time varies wildly across runners), and so are
``pallas_interpret`` cells — the debug interpreter's wall time is
Python-loop overhead with multi-x run-to-run variance, not a hot path
this gate protects. A first-pass regression is re-measured once and only
fails if it reproduces (per-cell best of both runs).

Exit code is nonzero on any regression, so the CI bench-smoke lane fails
when the hot paths the committed numbers document rot. Refresh the
committed smoke records (run the bench scripts with ``--smoke`` on the
reference machine and commit the JSONs) when a *deliberate* perf change
moves them.

Run:  PYTHONPATH=src python -m benchmarks.compare [--threshold 1.5]
          [--min-us 300] [--bench kernel,serve,energy,pareto] [--no-run]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import RESULTS_DIR

# timing leaves: key -> True when larger-is-better (throughput)
_TIME_KEYS = {"warm_us": False, "ttft_ms": False, "decode_tok_s": True,
              # spec_bench: wall time to the last token, seq vs spec
              "ttlt_ms": False,
              # traffic_bench wall-clock latency percentiles + goodput
              "ttft_p50_ms": False, "ttft_p99_ms": False,
              "tpot_p50_ms": False, "tpot_p99_ms": False,
              "goodput_tok_s": True,
              # goodput_bench: the drill's productive fraction of wall
              # clock — wall-derived, so ratio-gated, not exact
              "goodput_pct": True}
# deterministic leaves compared with exact equality (op-count drift gate +
# e2e_pareto frontier-membership gate + the static-analysis audit report —
# every audit leaf is a structural count over jaxpr traces, so any drift
# means ledger coverage changed without the golden being refreshed)
_EXACT_KEYS = ("ops_per_token", "analog_ops_per_token", "on_front",
               "front_size",
               # audit report leaves (experiments/audit/audit_report.json)
               "dot_generals", "convs", "tagged_values", "tagged_gains",
               "tagged_other", "declared_digital", "transposes", "untagged",
               "ledger_mismatches", "dtype_f32", "dtype_bf16", "calls",
               "macs", "ledger", "traced", "compiles", "fetches", "steps",
               "violations", "failures",
               # traffic_bench scheduling leaves: the virtual dispatch-cost
               # clock makes admission order, chunk slicing, completion and
               # queue-depth counts pure functions of the seeded traffic —
               # any drift means the scheduler's *decisions* changed, not a
               # machine got slower
               "completed", "completed_in_slo", "rejected", "preempted",
               "sched_steps", "decode_steps", "prefill_dispatches",
               "queue_depth_max", "generated_tokens", "goodput_tokens",
               "knee_rate_frac", "beats_static_above_capacity",
               "prefill_executables",
               # prefix_bench leaves: cache counters and token savings are
               # pure functions of the seeded shared-prefix traffic, and
               # the derived win booleans (bit-identical outputs, TTFT /
               # dispatch / prefill-energy wins of cache-on over
               # cache-off) are the tentpole's acceptance criteria — any
               # drift means the reuse policy changed without the record
               # being refreshed
               "prefix_hits", "prefix_misses", "prefix_inserts",
               "prefix_evictions", "prefix_bytes",
               "prefill_tokens_dispatched", "prefill_tokens_saved",
               "recompute_tokens_saved", "admission_reorders",
               "outputs_identical", "cache_wins_ttft",
               "cache_wins_dispatches", "prefill_pj_reduced",
               # goodput_bench drill counters: faults fire at scheduled
               # steps, detection runs on a virtual fleet clock, and the
               # async writer drains at fault boundaries — every counter
               # is a pure function of (arch, plan, config), so any drift
               # means the resilience *policy* changed
               "final_step", "attempts", "faults_injected",
               "faults_detected", "fault_kill", "fault_device_loss",
               "fault_straggler", "steps_recomputed", "ckpt_local",
               "ckpt_durable", "restore_local", "restore_durable",
               "remesh_events", "dp_width_initial", "dp_width_final",
               "trajectory_bit_identical", "step", "severity",
               # spec_bench leaves: self-draft greedy acceptance is
               # structurally total, so the acceptance counters and the
               # dispatch arithmetic are pure functions of the config;
               # outputs_identical (gated above) is the tentpole's
               # bit-exactness acceptance criterion, and energy_win is
               # the deterministic analytic pJ/accepted-token verdict
               "accepted_tokens_per_step", "spec_steps", "spec_tokens",
               "draft_dispatches", "verify_dispatches",
               "repair_dispatches", "energy_win")
# committed-value scale to microseconds, for the noise floor
_TO_US = {"warm_us": 1.0, "ttft_ms": 1e3, "ttlt_ms": 1e3,
          "ttft_p50_ms": 1e3,
          "ttft_p99_ms": 1e3, "tpot_p50_ms": 1e3, "tpot_p99_ms": 1e3}

# "audit" is gated by its own CI lane (which writes the report first and
# compares with --no-run), so it is not in the default bench set.
_BENCHES = ("kernel", "serve", "energy", "pareto", "traffic", "prefix",
            "goodput", "spec")

# records that don't live under experiments/bench/
_REL_OVERRIDE = {"audit_report": "experiments/audit/audit_report.json"}


def _walk(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, path + (k,))
    elif isinstance(tree, (int, float)) and path and (
            path[-1] in _TIME_KEYS or path[-1] in _EXACT_KEYS):
        yield path, float(tree)


def _lookup(tree, path):
    for k in path:
        if not isinstance(tree, dict) or k not in tree:
            return None
        tree = tree[k]
    return tree if isinstance(tree, (int, float)) else None


def compare(committed: dict, fresh: dict, *, threshold: float = 1.5,
            min_us: float = 300.0, label: str = "") -> list:
    """Return a list of regression strings (empty = clean)."""
    regressions = []
    for path, want in _walk(committed):
        if "pallas_interpret" in path:
            continue  # debug interpreter: not a guarded hot path
        key = path[-1]
        got = _lookup(fresh, path)
        if key in _EXACT_KEYS:
            # deterministic structure counts: any drift is a regression,
            # including the leaf disappearing from the fresh record
            if got is None or got != want:
                regressions.append(
                    f"{label}{'/'.join(path)}: op count {want:.6g} -> "
                    f"{'missing' if got is None else f'{got:.6g}'} "
                    "(exact-match leaf; models and committed energy "
                    "record disagree)")
            continue
        us = want * _TO_US.get(key, 0.0)
        if not _TIME_KEYS[key] and us < min_us:
            continue  # dispatch-overhead noise at smoke sizes
        if got is None or got <= 0 or want <= 0:
            continue  # shape/backend set changed; absence is not slowness
        ratio = (want / got) if _TIME_KEYS[key] else (got / want)
        if ratio > threshold:
            regressions.append(
                f"{label}{'/'.join(path)}: {want:.1f} -> {got:.1f} "
                f"({ratio:.2f}x worse, threshold {threshold}x)")
    return regressions


def _committed(name: str) -> dict:
    """The committed baseline record.

    Read from git HEAD when available: a fresh smoke run overwrites the
    working-tree JSON, so reading the file would make any *second* compare
    invocation (or --no-run) diff a record against itself and pass
    vacuously. Falls back to the working-tree file outside a checkout."""
    rel = _REL_OVERRIDE.get(name, f"experiments/bench/{name}.json")
    root = os.path.abspath(os.path.join(RESULTS_DIR, "..", ".."))
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{rel}"], cwd=root,
            capture_output=True, check=True, text=True).stdout
        return json.loads(blob)
    except (OSError, subprocess.CalledProcessError, ValueError):
        pass
    return _on_disk(name)


def _on_disk(name: str) -> dict:
    """The working-tree record (what a just-finished smoke run wrote)."""
    if name in _REL_OVERRIDE:
        root = os.path.abspath(os.path.join(RESULTS_DIR, "..", ".."))
        path = os.path.join(root, _REL_OVERRIDE[name])
    else:
        path = os.path.join(RESULTS_DIR, f"{name}.json")
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _merge_records(a, b, path=()):
    """Elementwise best of two bench records: min for time-like leaves,
    max for throughput-like — a regression must reproduce across runs."""
    if isinstance(a, dict) and isinstance(b, dict):
        out = dict(b)
        for k, v in a.items():
            out[k] = _merge_records(v, b[k], path + (k,)) if k in b else v
        return out
    if (isinstance(a, (int, float)) and isinstance(b, (int, float))
            and path and path[-1] in _TIME_KEYS):
        return max(a, b) if _TIME_KEYS[path[-1]] else min(a, b)
    return a


def _fresh_run(bench: str):
    if bench == "kernel":
        from benchmarks import kernel_bench
        return kernel_bench.run(smoke=True)
    if bench == "energy":
        from benchmarks import e2e_energy
        return e2e_energy.run(**e2e_energy.SMOKE_PARAMS)
    if bench == "pareto":
        from benchmarks import e2e_energy
        return e2e_energy.run_pareto(**e2e_energy.PARETO_SMOKE_PARAMS)
    if bench == "audit":
        from repro.analysis.cli import build_report
        from repro.configs import list_configs
        return build_report(list(list_configs()), verbose=False)
    if bench == "traffic":
        from benchmarks import traffic_bench
        return traffic_bench.run(**traffic_bench.SMOKE_PARAMS)
    if bench == "prefix":
        from benchmarks import traffic_bench
        return traffic_bench.run_shared_prefix(
            **traffic_bench.SHARED_SMOKE_PARAMS)
    if bench == "goodput":
        from benchmarks import goodput_bench
        return goodput_bench.run(**goodput_bench.SMOKE_PARAMS)
    if bench == "spec":
        from benchmarks import spec_bench
        return spec_bench.run(**spec_bench.SMOKE_PARAMS)
    from benchmarks import serve_bench
    return serve_bench.run(**serve_bench.SMOKE_PARAMS)


def run(benches=_BENCHES, threshold=1.5, min_us=300.0, fresh=True) -> list:
    """Returns the regression list (empty = clean). The committed record is
    snapshotted into memory *before* the fresh smoke run overwrites the
    on-disk JSON. A first-run regression is re-measured once and the
    per-cell best of both runs is compared — transient scheduler noise on
    shared runners must not fail the gate, a real slowdown reproduces.
    ``fresh=False`` compares the on-disk records against the git-HEAD
    baseline without running anything (for use after separate smoke
    steps)."""
    regressions = []
    names = {"kernel": "kernel_bench_smoke", "serve": "serve_bench_smoke",
             "energy": "e2e_energy_smoke", "pareto": "e2e_pareto_smoke",
             "traffic": "traffic_bench_smoke",
             "prefix": "prefix_bench_smoke",
             "goodput": "goodput_bench_smoke",
             "spec": "spec_bench_smoke", "audit": "audit_report"}
    for bench in benches:
        name = names[bench]
        committed = _committed(name)
        new = _fresh_run(bench) if fresh else _on_disk(name)
        found = compare(committed, new, threshold=threshold, min_us=min_us,
                        label=f"{bench}:")
        if found and fresh:
            print(f"[compare] {bench}: {len(found)} candidate regression(s); "
                  "re-measuring to confirm")
            new = _merge_records(new, _fresh_run(bench))
            found = compare(committed, new, threshold=threshold,
                            min_us=min_us, label=f"{bench}:")
        regressions += found
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="warm-time ratio above which a cell is a regression")
    ap.add_argument("--min-us", type=float, default=300.0,
                    help="skip committed cells faster than this (noise floor)")
    ap.add_argument("--bench",
                    default="kernel,serve,energy,pareto,traffic,prefix,"
                            "goodput,spec",
                    help="comma list: kernel,serve,energy,pareto,traffic,"
                         "prefix,goodput,spec,audit "
                         "(audit gates experiments/audit/audit_report.json "
                         "exactly; its CI lane runs the CLI then this with "
                         "--no-run)")
    ap.add_argument("--no-run", action="store_true",
                    help="compare records already on disk instead of "
                         "running fresh --smoke benches")
    args = ap.parse_args()
    regressions = run(
        tuple(b.strip() for b in args.bench.split(",") if b.strip()),
        threshold=args.threshold, min_us=args.min_us, fresh=not args.no_run)
    if regressions:
        print("\n[compare] BENCH REGRESSIONS:", file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        raise SystemExit(1)
    print("[compare] no bench regressions "
          f"(threshold {args.threshold}x, floor {args.min_us:.0f}us)")


if __name__ == "__main__":
    main()
