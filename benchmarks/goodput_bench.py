"""GoodPut fault-drill benchmark: supervised failure injection, per arch.

Runs the ``training.supervisor`` drill harness end-to-end on 2+ archs:
training under a seeded ``FaultPlan`` (one process kill, one simulated
device loss, one injected straggler by default), with async two-tier
checkpointing, heartbeat-driven detection, freshest-tier restore, and
elastic resume at a smaller data-parallel width after device loss.
Reports, per arch:

* the drill counters — faults injected / detected (by kind),
  checkpoints and restores per tier, steps recomputed, remesh events,
  logical DP width before/after, attempts, final step;
* the GoodPut partition — wall seconds per bucket (productive /
  recompute / checkpoint_stall / detection / recovery / overhead) and
  ``goodput_pct``, next to an uninterrupted baseline run's;
* the energy story — pJ/token from the arch's CIM train trace, inflated
  by recompute into ``pj_per_useful_token`` (BadPut priced through the
  CostLedger);
* ``trajectory_bit_identical`` — whether the drilled run's loss at every
  step matched the uninterrupted baseline's bit-for-bit (the
  (seed, step)-pure pipeline + exact checkpoint roundtrip make this a
  provable invariant, and the supervisor additionally asserts it inline
  on every recomputed step).

Determinism contract (the CI gate): faults fire at scheduled steps of a
deterministic loop, the fleet heartbeats on a virtual clock, and the
async writer is drained at each fault boundary — so every counter above
is a pure function of (arch, plan, config) and is compared with EXACT
equality by benchmarks/compare.py. ``goodput_pct`` is wall-clock-derived
and gets the usual ratio gate.

Run:  PYTHONPATH=src python -m benchmarks.goodput_bench [--smoke]
"""
import argparse
import tempfile

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.training.fault import FaultPlan, make_fault_plan
from repro.training.supervisor import DrillConfig, Supervisor, price_drill
from repro.training.trainer import TrainConfig
from benchmarks.common import emit, save_json

# the same two cache-state extremes the traffic bench sweeps: attention
# (KV growth) and SSM (fixed recurrent state)
ARCHS = [
    ("attn", "qwen2-1.5b"),
    ("ssm", "mamba2-1.3b"),
]

SMOKE_PARAMS = dict(steps=8, batch=2, seq_len=16, local_every=2,
                    durable_every=4, n_faults=3,
                    record="goodput_bench_smoke")


def bench_arch(name, *, steps, batch, seq_len, local_every, durable_every,
               n_faults, seed=0):
    arch = get_config(name).reduced().replace(n_layers=2)
    pipe = SyntheticLM(DataConfig(global_batch=batch, seq_len=seq_len,
                                  vocab_size=arch.vocab_size, seed=seed))
    tcfg = TrainConfig(steps=steps)
    plan = make_fault_plan(seed, steps, n_faults=n_faults)

    def drill(fault_plan):
        with tempfile.TemporaryDirectory() as wd:
            dcfg = DrillConfig(workdir=wd, steps=steps,
                               local_every=local_every,
                               durable_every=durable_every,
                               n_hosts=4, n_chips=8)
            return Supervisor(arch, tcfg, dcfg, pipe, fault_plan,
                              seed=seed).run_drill()

    rep = drill(plan)
    base = drill(FaultPlan(()))

    res = {
        "plan": {f"e{i}": {"step": e.step, "severity": e.severity,
                           "kind_" + e.kind: 1}
                 for i, e in enumerate(plan.events)},
        "drill": {k: v for k, v in rep.items()
                  if k not in ("losses", "goodput")},
        "goodput": rep["goodput"],
        "baseline": {"goodput_pct": base["goodput"]["goodput_pct"],
                     "wall_s": base["goodput"]["wall_s"]},
        "trajectory_bit_identical": rep["losses"] == base["losses"],
        "energy": price_drill(arch, rep, tokens_per_step=batch * seq_len,
                              seed=seed),
    }
    emit(f"goodput/{name}/drill", rep["goodput"]["wall_s"] * 1e6,
         f"goodput={rep['goodput']['goodput_pct']:.1f}%"
         f";detected={rep['faults_detected']}/{rep['faults_injected']}")
    return res


def run(steps=16, batch=4, seq_len=32, local_every=2, durable_every=6,
        n_faults=3, archs=None, record="goodput_bench", seed=0):
    out = {
        "params": {"steps": steps, "batch": batch, "seq_len": seq_len,
                   "local_every": local_every,
                   "durable_every": durable_every, "n_faults": n_faults,
                   "seed": seed},
        "archs": {},
    }
    for label, name in (archs or ARCHS):
        out["archs"][label] = {
            "config": name,
            **bench_arch(name, steps=steps, batch=batch, seq_len=seq_len,
                         local_every=local_every,
                         durable_every=durable_every, n_faults=n_faults,
                         seed=seed)}

    print(f"\n{'arch':<6} {'detected':>9} {'recomp':>7} {'attempts':>9} "
          f"{'goodput%':>9} {'base%':>7} {'bit-id':>7} "
          f"{'pJ/tok':>10} {'pJ/useful':>10}")
    for label, a in out["archs"].items():
        d, g, e = a["drill"], a["goodput"], a["energy"]
        print(f"{label:<6} {d['faults_detected']:>4}/{d['faults_injected']:<4} "
              f"{d['steps_recomputed']:>7} {d['attempts']:>9} "
              f"{g['goodput_pct']:>9.1f} {a['baseline']['goodput_pct']:>7.1f} "
              f"{str(a['trajectory_bit_identical']):>7} "
              f"{e['pj_per_token']:>10.1f} {e['pj_per_useful_token']:>10.1f}")
        print(f"{label:<6} dp {d['dp_width_initial']}->{d['dp_width_final']}; "
              f"ckpt local/durable {d['ckpt_local']}/{d['ckpt_durable']}; "
              f"restores local/durable "
              f"{d['restore_local']}/{d['restore_durable']}")
    save_json(record, out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--faults", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the CI bench lane")
    args = ap.parse_args()
    if args.smoke:
        # separate record: a smoke run must not clobber the committed
        # full-size goodput_bench.json
        run(**SMOKE_PARAMS)
    else:
        run(steps=args.steps, batch=args.batch, n_faults=args.faults)
