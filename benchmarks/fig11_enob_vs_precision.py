"""Paper Fig. 11: required ADC ENOB vs input precision (N_M,x sweep).

N_E,x = 3 (so the studied distributions fit in range), weights FP4_E2M1
max-entropy, N_R = 32.  Validates the linear ENOB-vs-precision scaling and
the 1.5–6 b advantage holding independent of input resolution.
"""
import time

import jax
import numpy as np

from repro.core import adc as A
from repro.core import distributions as D
from repro.core import formats as F
from benchmarks.common import emit, save_json


def run():
    key = jax.random.PRNGKey(0)
    table = {}
    for nm in [1, 2, 3, 4, 5]:
        fmt = F.FPFormat(3, nm)
        for dname, dist in [
            ("uniform", D.uniform()),
            ("gauss_outliers", D.gaussian_outliers()),
        ]:
            t0 = time.perf_counter()
            rc = A.required_enob(key, "conv", dist, fmt)
            ru = A.required_enob(key, "gr_unit", dist, fmt)
            us = (time.perf_counter() - t0) / 2 * 1e6
            table[f"NM{nm}_{dname}"] = {
                "conv": rc.enob, "gr_unit": ru.enob,
                "delta": rc.enob - ru.enob,
            }
            emit(f"fig11/NM{nm}/{dname}", us,
                 f"conv={rc.enob:.2f};gr_unit={ru.enob:.2f}")
    # linear scaling: ENOB grows ~1 b per mantissa bit
    u = [table[f"NM{nm}_uniform"]["gr_unit"] for nm in (1, 2, 3, 4, 5)]
    slope = np.polyfit([1, 2, 3, 4, 5], u, 1)[0]
    deltas = [table[f"NM{nm}_uniform"]["delta"] for nm in (1, 2, 3, 4, 5)]
    out = {"table": table, "slope_bits_per_mantissa_bit": float(slope),
           "delta_range": [min(deltas), max(deltas)]}
    save_json("fig11", out)
    return out


if __name__ == "__main__":
    run()
