"""Fig. 4 condition validation: N_eff, signal-power preservation, and
ideal-ADC exactness of the GR-MAC column simulators (paper §III-B2)."""
import jax
import jax.numpy as jnp

from repro.core import distributions as D
from repro.core import formats as F
from repro.core import mac as M
from benchmarks.common import emit, save_json, time_call


def run():
    key = jax.random.PRNGKey(0)
    dist = D.gaussian_clipped(4.0)
    kx, kw = jax.random.split(key)
    out = {}
    for fmt in [F.FP6_E2M3, F.FP6_E3M2]:
        xs = F.quantize(dist(kx, (8192, 32)), fmt)
        ws = F.quantize(dist(kw, (8192, 32)), fmt)
        us = time_call(
            lambda a, b: M.gr_mac_unit(a, b, fmt, fmt, 8.0).z_hat, xs, ws,
            n_iter=3)
        gu = M.gr_mac_unit(xs, ws, fmt, fmt, 16.0)
        ii = M.int_mac(xs, ws, 16.0)
        neff = float(jnp.mean(gu.n_eff))
        pratio = float(jnp.mean(gu.v ** 2) / jnp.mean(ii.v ** 2))
        denob = 0.5 * float(jnp.log2(pratio))
        err = float(jnp.max(jnp.abs(gu.z - jnp.sum(xs * ws, -1))))
        out[fmt.name] = {"n_eff": neff, "power_ratio": pratio,
                         "delta_enob": denob, "ideal_err": err}
        emit(f"mac/{fmt.name}", us,
             f"neff={neff:.1f};power_x={pratio:.1f};dENOB={denob:.2f}")
    # mismatch robustness (paper §III-E1): K_C in 0.45–0.85 %·sqrt(fF)
    fmt = F.FP6_E2M3
    xs = F.quantize(dist(kx, (8192, 32)), fmt)
    ws = F.quantize(dist(kw, (8192, 32)), fmt)
    _, _, e = F.decompose(xs, fmt)
    for kc in (0.45, 0.85):
        gerr = M.mismatch_gains(jax.random.PRNGKey(5), e, kc)
        gm = M.gr_mac_row(xs, ws, fmt, 16.0, gain_err=gerr)
        g0 = M.gr_mac_row(xs, ws, fmt, 16.0)
        rel = float(jnp.sqrt(jnp.mean((gm.z_hat - g0.z_hat) ** 2)
                             / jnp.mean(g0.z_hat ** 2)))
        out[f"mismatch_kc{kc}"] = rel
        emit(f"mac/mismatch_kc{kc}", 0.0, f"rel_rms_err={rel:.4f}")
    save_json("mac_validation", out)
    return out


if __name__ == "__main__":
    run()
