"""Paper Fig. 12: CIM energy/Op across the (DR, SQNR) design space, plus the
pie-chart design points (FP4_E2M1, FP6_E3M2, FP8*_E4M3) and the ADC
parameter sensitivity study (C7).

Validates C5 (SQNR- vs DR-dominated scaling; iso-energy DR gains) and C6
(FP4 ~23 % improvement; FP6_E3M2 native ~29 fJ/Op).
"""
import time

import jax

from repro.core import dse as S
from repro.core import energy as E
from repro.core import formats as F
from benchmarks.common import emit, save_json

ENERGY_LIMIT_FJ = 100.0


def run():
    key = jax.random.PRNGKey(2)
    t0 = time.perf_counter()
    pts = S.explore(key, n_exps=(0, 1, 2, 3), n_mans=(1, 2, 3, 4, 5, 6),
                    n_cols=1 << 12)
    us = (time.perf_counter() - t0) * 1e6 / len(pts)
    grid = []
    for p in pts:
        row = {
            "fmt": p.fmt_x.name, "dr_db": p.dr_db, "sqnr_db": p.sqnr_db,
            "conv_fj": p.conv.total if p.conv else None,
            "gr_fj": p.gr.total if p.gr else None,
            "gr_arch": p.gr_arch,
            "enob_conv": p.enob_conv, "enob_gr": p.enob_gr,
        }
        grid.append(row)
        emit(f"fig12/{p.fmt_x.name}", us,
             f"conv={row['conv_fj']:.1f};gr={row['gr_fj'] if row['gr_fj'] else -1:.1f}")

    # --- iso-energy DR gain (C5): contour comparison ---
    # At a fixed SQNR row, how many excess-DR bits (e_max - 1) can each
    # architecture afford within an energy budget? Fig. 12 labels mantissa
    # bits including the implicit one, so "35 dB" = stored N_M = 3
    # (6.02*4+10.79 = 34.9 dB) and "47 dB" = stored N_M = 5.
    def max_affordable_dr_bits(nm, budget_fj, which):
        best = -1
        for b_bits, fmt in [(0, F.IntFormat(nm + 2)),
                            (1, F.FPFormat(1, nm)),
                            (2, F.FPFormat(2, nm)),
                            (6, F.FPFormat(3, nm))]:
            p = S.evaluate_point(key, fmt, n_cols=1 << 12)
            e = p.conv if which == "conv" else p.gr
            if e is not None and e.total <= budget_fj:
                best = max(best, b_bits)
        return best

    # The strict-budget contour is knee-sensitive (±1 b of ENOB calibration
    # moves the affordable-B step); anchor the budget at the energy the
    # GR-CIM needs for its full gain-ranging span (B=6) and report it.
    gr_b6_35 = S.evaluate_point(key, F.FPFormat(3, 3), n_cols=1 << 12).gr
    budget_35 = gr_b6_35.total if gr_b6_35 else 30.0
    dr_gain_35db = 6 - max(0, max_affordable_dr_bits(3, budget_35, "conv"))
    dr_gain_47db_100fj = (max_affordable_dr_bits(5, 100.0, "gr")
                          - max(0, max_affordable_dr_bits(5, 100.0, "conv")))

    # --- design points (pie charts) ---
    fp4 = S.evaluate_point(key, F.FP4_E2M1, n_cols=1 << 13)
    fp6 = S.evaluate_point(key, F.FP6_E3M2, n_cols=1 << 13)
    fp4_improvement = (fp4.conv.total - fp4.gr.total) / fp4.conv.total

    # --- FP8*_E4M3: needs global normalization for either architecture ---
    # (e_max=15 exceeds the 6-octave gain-ranging span). The GR array
    # processes the post-normalization FP(3,3) segment natively; the
    # wrapper cost is the paper-external overhead model.
    from repro.core.energy import global_norm_energy_per_op_fj
    seg = S.evaluate_point(key, F.FPFormat(3, 3), n_cols=1 << 12)
    gnorm = global_norm_energy_per_op_fj(
        width_bits=F.FP8_E4M3.n_man + 1 + 6, shift_range=2 ** 4,
        n_r=32, n_c=32)
    fp8_star = {"segment_gr_fj": seg.gr.total if seg.gr else None,
                "global_norm_overhead_fj": gnorm,
                "total_fj": (seg.gr.total + gnorm) if seg.gr else None}
    emit("fig12/FP8*_E4M3_globalnorm", 0.0,
         f"gr+wrapper={fp8_star['total_fj']:.1f}")

    # --- C7: ADC parameter sensitivity (±10 % on k1, k2) ---
    sens = {}
    for tag, f in [("nominal", 1.0), ("+10%", 1.1), ("-10%", 0.9)]:
        p = E.TechParams(k1_ff=100.0 * f, k2_ff=1e-3 * f)
        pt = S.evaluate_point(key, F.FP4_E2M1, p=p, n_cols=1 << 13)
        sens[tag] = (pt.conv.total - pt.gr.total) / pt.conv.total
        emit(f"fig12/sens{tag}", 0.0, f"improvement={sens[tag]*100:.1f}%")

    out = {
        "grid": grid,
        "fp4": {"conv_fj": fp4.conv.total, "gr_fj": fp4.gr.total,
                "improvement": fp4_improvement, "gr_arch": fp4.gr_arch},
        "fp6_e3m2": {"gr_fj": fp6.gr.total, "conv_fj": fp6.conv.total,
                     "conv_out_of_range": fp6.conv.total > ENERGY_LIMIT_FJ,
                     "gr_native": fp6.gr.total < ENERGY_LIMIT_FJ},
        "fp8_star": fp8_star,
        "dr_gain_bits_at_35db_iso_energy": dr_gain_35db,
        "iso_energy_budget_35db_fj": budget_35,
        "dr_gain_bits_at_47db_100fj": dr_gain_47db_100fj,
        "sensitivity": sens,
    }
    save_json("fig12", out)
    return out


if __name__ == "__main__":
    run()
