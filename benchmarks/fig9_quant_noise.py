"""Paper Fig. 9: output quantization noise vs exponent bits per distribution.

Reproduces the key observations: (i) global SQNR saturates quickly with
exponent bits for outlier-heavy data, (ii) the Gaussian+outliers CORE is
unresolved (near-zero SQNR) until N_E,x >= 3, then plateaus at N_E,x = 4.
"""
import jax

from repro.core import distributions as D
from repro.core import formats as F
from benchmarks.common import emit, save_json, time_call


def core_sqnr(key, fmt, eps=0.01, k=50.0, n=1 << 20):
    """SQNR restricted to non-outlier (core) samples."""
    sigma = 1.0 / (3.0 * k)
    x = sigma * jax.random.normal(key, (n,))
    xq = F.quantize(x, fmt)
    return float(F.measured_sqnr_db(x, xq))


def run():
    key = jax.random.PRNGKey(0)
    n_m = 2
    rows = {}
    for ne in [1, 2, 3, 4, 5]:
        fmt = F.FPFormat(ne, n_m)
        for dname, dist in [
            ("uniform", D.uniform()),
            ("max_entropy", D.max_entropy(fmt)),
            ("gauss_outliers", D.gaussian_outliers()),
        ]:
            x = dist(key, (1 << 20,))
            xq = F.quantize(x, fmt)
            us = time_call(lambda xx: F.quantize(xx, fmt), x, n_iter=3)
            sq = float(F.measured_sqnr_db(x, xq))
            rows[f"NE{ne}_{dname}"] = sq
            emit(f"fig9/NE{ne}/{dname}", us, f"sqnr_db={sq:.2f}")
        sq_core = core_sqnr(key, fmt)
        rows[f"NE{ne}_gauss_outliers_core"] = sq_core
        emit(f"fig9/NE{ne}/gauss_outliers_core", 0.0, f"sqnr_db={sq_core:.2f}")
    # paper observations
    obs = {
        "core_unresolved_at_NE2": rows["NE2_gauss_outliers_core"] < 10.0,
        "core_resolved_at_NE3": rows["NE3_gauss_outliers_core"] > F.sqnr_db(F.FPFormat(3, n_m)) - 6.0,
        "core_plateau_at_NE4": abs(rows["NE4_gauss_outliers_core"] - rows["NE5_gauss_outliers_core"]) < 1.5,
    }
    save_json("fig9", {"rows": rows, "observations": obs})
    return {"rows": rows, "observations": obs}


if __name__ == "__main__":
    run()
