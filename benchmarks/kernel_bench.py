"""GR-MAC backend benchmark: wall time and oracle agreement per backend.

Sweeps the dispatchable backends (``--backend all`` or a comma list) over
the three granularities and emits a comparison table, so the fast XLA
path's speedup over interpret-mode Pallas is *measured*, not asserted.

Two times per cell:

* ``cold``  — first call on a fresh executable: trace + compile + run.
  This is the cost that made interpret-mode Pallas unusable off-TPU
  (the interpreter traces the kernel body per grid step; every new
  shape/config pays it again).
* ``warm``  — steady-state per-call time after compilation.

Two shapes by default, both recorded to experiments/bench/kernel_bench.json:

* ``edge_decode`` (16×768×3072, paper-cim-120m FFN) — the paper's
  deployment regime: small-M matmuls are where the CIM path actually runs
  per decoded token, and where the Pallas path's mandatory 128-alignment
  padding wastes the most work.
* ``train_large_m`` (2048×768×3072) — the training-shape regime the
  ROADMAP flagged: the blocked einsum is bandwidth-bound there, and the
  fused ``tiled`` backend is the fix (the headline
  ``tiled_warm_speedup_over_{ref,xla}`` rows record its win).
  ``pallas_interpret`` is excluded here (the interpreter would take hours
  at this size, and the debug cross-check adds nothing at scale).

Select named shapes with ``--shapes train_large_m`` (comma list), or
override with --m/--k/--n for a single custom shape; --smoke runs one tiny
shape with minimal iterations (the CI bench lane). Named-subset and custom
runs write ``kernel_bench_partial``/``kernel_bench_custom`` records so
they can never clobber the committed full-sweep numbers.

On TPU the figure of merit for the ``pallas`` backend is the lowered
structure; off-TPU ``pallas`` is skipped (it would silently interpret)
and ``pallas_interpret`` carries the debug cross-check.
"""
import argparse
import time

import jax
import numpy as np

from repro.core.formats import FP4_E2M1, FP6_E3M2, quantize
from repro.kernels.dispatch import grmac_matmul
from benchmarks.common import emit, save_json, time_call

_DEFAULT_BACKENDS = ("xla", "tiled", "ref", "pallas_interpret")
_GRANS = ["conv", "row", "unit"]
_SHAPES = {
    "edge_decode": (16, 768, 3072),
    "train_large_m": (2048, 768, 3072),
}
_SMOKE_SHAPE = (8, 96, 64)
# backends too slow to run at a given shape (documented above)
_SHAPE_SKIP = {"train_large_m": {"pallas_interpret"}}


def run_shape(backends, m, k, n, n_iter=5):
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    x = jax.random.uniform(kx, (m, k), minval=-1, maxval=1)
    w = quantize(jax.random.uniform(kw, (k, n), minval=-1, maxval=1), FP4_E2M1)
    out = {"shape": [m, k, n], "backends": {}}
    results = {}
    for gran in _GRANS:
        kwargs = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
                      granularity=gran)
        for b in backends:
            # jit the full dispatch for every backend so cells are
            # apples-to-apples (the ref oracle is not internally jitted)
            fn = jax.jit(
                lambda a, bb, _b=b: grmac_matmul(a, bb, backend=_b, **kwargs))
            t0 = time.perf_counter()
            got = jax.block_until_ready(fn(x, w))
            cold_us = (time.perf_counter() - t0) * 1e6
            interp = b == "pallas_interpret"
            warm_us = time_call(fn, x, w,
                                n_iter=min(3, n_iter) if interp else n_iter,
                                warmup=0)
            results[(b, gran)] = np.asarray(got)
            out["backends"].setdefault(b, {})[gran] = {
                "cold_us": cold_us, "warm_us": warm_us}
            emit(f"kernel/{m}x{k}x{n}/{b}/{gran}", warm_us,
                 f"cold_us={cold_us:.0f}")
        # oracle agreement (ref is always exact-by-construction)
        oracle = results.get(("ref", gran))
        if oracle is not None:
            for b in backends:
                ok = bool(np.allclose(results[(b, gran)], oracle, atol=1e-5))
                out["backends"][b][gran]["allclose"] = ok

    # comparison table + headline speedups
    hdr = " ".join(f"{g + ' cold/warm(us)':>24}" for g in _GRANS)
    print(f"\nshape {m}x{k}x{n}\n{'backend':<18} {hdr}")
    for b in backends:
        per = out["backends"][b]
        print(f"{b:<18} " + " ".join(
            f"{per[g]['cold_us']:>13.0f}/{per[g]['warm_us']:>10.1f}"
            for g in _GRANS))
    if "xla" in out["backends"] and "pallas_interpret" in out["backends"]:
        pi, xl = out["backends"]["pallas_interpret"], out["backends"]["xla"]
        out["xla_cold_speedup_over_interpret"] = {
            g: pi[g]["cold_us"] / xl[g]["cold_us"] for g in _GRANS}
        out["xla_warm_speedup_over_interpret"] = {
            g: pi[g]["warm_us"] / xl[g]["warm_us"] for g in _GRANS}
        print("xla speedup over pallas_interpret (cold trace+compile+run): "
              + ", ".join(f"{g}={v:.0f}x" for g, v in
                          out["xla_cold_speedup_over_interpret"].items()))
        print("xla speedup over pallas_interpret (warm steady-state):      "
              + ", ".join(f"{g}={v:.1f}x" for g, v in
                          out["xla_warm_speedup_over_interpret"].items()))
        warm = list(out["xla_warm_speedup_over_interpret"].values())
        gm = float(np.exp(np.mean(np.log(warm))))
        out["xla_warm_speedup_geomean"] = gm
        print(f"geomean warm speedup: {gm:.1f}x")
    if "xla" in out["backends"] and "ref" in out["backends"]:
        rf, xl = out["backends"]["ref"], out["backends"]["xla"]
        out["xla_warm_speedup_over_ref"] = {
            g: rf[g]["warm_us"] / xl[g]["warm_us"] for g in _GRANS}
    if "tiled" in out["backends"]:
        td = out["backends"]["tiled"]
        for base in ("ref", "xla"):
            if base not in out["backends"]:
                continue
            bs = out["backends"][base]
            sp = {g: bs[g]["warm_us"] / td[g]["warm_us"] for g in _GRANS}
            out[f"tiled_warm_speedup_over_{base}"] = sp
            print(f"tiled speedup over {base} (warm): "
                  + ", ".join(f"{g}={v:.2f}x" for g, v in sp.items()))
    return out


def run(backends=None, shapes=None, smoke=False, n_iter=5, record=None):
    """``record`` names the JSON written under experiments/bench/. Only the
    full default sweep (all default backends, all named shapes) writes the
    committed ``kernel_bench`` record — smoke/custom/partial runs get their
    own file so a quick local run can never clobber the measured numbers
    the ROADMAP cites. ``shapes`` may be a {label: (m, k, n)} dict or a
    list of names from ``_SHAPES``."""
    explicit_backends = bool(backends) and backends != ["all"]
    if not explicit_backends:
        backends = list(_DEFAULT_BACKENDS)
        if jax.default_backend() == "tpu":
            backends.insert(0, "pallas")
    if isinstance(shapes, (list, tuple)):
        unknown = [s for s in shapes if s not in _SHAPES]
        if unknown:
            raise SystemExit(
                f"unknown shape names {unknown}; choose from "
                f"{sorted(_SHAPES)}")
        shapes = {s: _SHAPES[s] for s in shapes}
    default_sweep = (shapes is None or shapes == _SHAPES) \
        and not smoke and not explicit_backends
    # only a *plain* --smoke run (default backends, no shape selection) may
    # write the committed kernel_bench_smoke record the CI compare gate
    # diffs against; any named/custom/partial combination gets _partial
    plain_smoke = smoke and shapes is None and not explicit_backends
    if shapes is None:
        shapes = {"smoke": _SMOKE_SHAPE} if smoke else dict(_SHAPES)
    if smoke:
        n_iter = 2
    out = {"shapes": {}}
    for label, (m, k, n) in shapes.items():
        bl = [b for b in backends if b not in _SHAPE_SKIP.get(label, ())]
        out["shapes"][label] = run_shape(bl, m, k, n, n_iter=n_iter)
    if record is None:
        record = ("kernel_bench" if default_sweep
                  else "kernel_bench_smoke" if plain_smoke
                  else "kernel_bench_partial")
    save_json(record, out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="all",
                    help="'all' or comma list of dispatch backends "
                         "(xla,tiled,ref,pallas,pallas_interpret)")
    ap.add_argument("--shapes", default="",
                    help="comma list of named shapes "
                         f"({','.join(_SHAPES)}); empty -> default sweep")
    ap.add_argument("--m", type=int, default=0,
                    help="custom shape (with --k/--n); 0 -> default sweep")
    ap.add_argument("--k", type=int, default=768)
    ap.add_argument("--n", type=int, default=3072)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape + minimal iterations (CI bench lane)")
    args = ap.parse_args()
    if args.m:
        shapes = {"custom": (args.m, args.k, args.n)}
    elif args.shapes:
        shapes = [s.strip() for s in args.shapes.split(",") if s.strip()]
    else:
        shapes = None
    run([b.strip() for b in args.backend.split(",")],
        shapes=shapes, smoke=args.smoke)
