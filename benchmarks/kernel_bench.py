"""GR-MAC Pallas kernel benchmark: wall time (interpret mode on CPU — the
TPU figure of merit is the lowered structure, not this wall time) and
agreement with the jnp reference across granularities."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import FP4_E2M1, FP6_E3M2, quantize
from repro.kernels.grmac_matmul import grmac_matmul_pallas
from repro.kernels.ref import grmac_matmul_ref
from benchmarks.common import emit, save_json, time_call


def run():
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    m = k = n = 256
    x = jax.random.uniform(kx, (m, k), minval=-1, maxval=1)
    w = quantize(jax.random.uniform(kw, (k, n), minval=-1, maxval=1), FP4_E2M1)
    out = {}
    for gran in ["conv", "row", "unit"]:
        kwargs = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
                      granularity=gran)
        ref = grmac_matmul_ref(x, w, **kwargs)
        us_ref = time_call(
            jax.jit(lambda a, b: grmac_matmul_ref(a, b, **kwargs)), x, w,
            n_iter=3)
        got = grmac_matmul_pallas(x, w, interpret=True, **kwargs)
        ok = bool(np.allclose(np.asarray(got), np.asarray(ref), atol=1e-5))
        us_k = time_call(
            lambda a, b: grmac_matmul_pallas(a, b, interpret=True, **kwargs),
            x, w, n_iter=1, warmup=1)
        out[gran] = {"ref_us": us_ref, "kernel_interpret_us": us_k,
                     "allclose": ok}
        emit(f"kernel/{gran}", us_ref, f"allclose={ok}")
    save_json("kernel_bench", out)
    return out


if __name__ == "__main__":
    run()
