"""Paper Fig. 8 analogue: GR-MAC transfer-function linearity.

The silicon validation sweeps (a) W at each exponent E -> linear response
with bounded DNL/INL, (b) E across W -> exponential response. Our numerical
equivalent drives a single GR-MAC cell across its full input grid and checks
(i) exact linearity in the mantissa word at fixed exponent, (ii) exact
2^E scaling across exponents, (iii) DNL/INL under Pelgrom mismatch stays
within 1/2 LSB for K_C in the paper's measured 0.45-0.85 %·sqrt(fF) range.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import mac as M
from benchmarks.common import emit, save_json

FMT = F.FP6_E2M3   # the paper's implemented configuration


def _cell_response(w_vals, e_fixed, gain_err=None):
    """Single-cell column (n_r=1): output vs weight mantissa at fixed E."""
    x = jnp.full_like(w_vals, 0.96875)          # max-mantissa input
    xq = F.quantize(x, FMT)
    wq = F.compose(jnp.ones_like(w_vals), w_vals,
                   jnp.full(w_vals.shape, e_fixed, jnp.int32), FMT)
    out = M.gr_mac_unit(xq[:, None], wq[:, None], FMT, FMT, 30.0,
                        gain_err=gain_err)
    return np.asarray(out.z_hat)


def run():
    out = {}
    # (a) W sweep at each E: response linear in the mantissa
    m_grid = jnp.arange(2 ** (FMT.n_man + 1)) / 2 ** (FMT.n_man + 1)
    worst_inl = 0.0
    for e in range(1, FMT.e_max + 1):
        z = _cell_response(m_grid, e)
        fit = np.polyfit(np.asarray(m_grid), z, 1)
        resid = z - np.polyval(fit, np.asarray(m_grid))
        lsb = float(z[1] - z[0]) if len(z) > 1 else 1.0
        inl = float(np.max(np.abs(resid)) / max(abs(lsb), 1e-12))
        worst_inl = max(worst_inl, inl)
        emit(f"fig8/linearity_E{e}", 0.0, f"inl_lsb={inl:.4f}")
    out["nominal_worst_inl_lsb"] = worst_inl

    # (b) E sweep: exact 2^E gain steps
    m_fixed = jnp.full((FMT.e_max,), 0.75)
    es = jnp.arange(1, FMT.e_max + 1, dtype=jnp.int32)
    wq = F.compose(jnp.ones_like(m_fixed), m_fixed, es, FMT)
    z = np.asarray(M.gr_mac_unit(
        jnp.full((FMT.e_max, 1), 0.9375), wq[:, None], FMT, FMT, 30.0).z_hat)
    ratios = z[1:] / z[:-1]
    out["gain_step_ratios"] = ratios.tolist()
    emit("fig8/exp_gain", 0.0,
         f"ratios={[round(float(r),3) for r in ratios]}")

    # (c) mismatch Monte Carlo: DNL within 1/2 LSB (paper's 3-sigma claim)
    rng = jax.random.PRNGKey(0)
    for kc in (0.45, 0.85):
        worst = 0.0
        for trial in range(64):
            rng, sub = jax.random.split(rng)
            gerr = M.mismatch_gains(
                sub, jnp.full((len(m_grid), 1), FMT.e_max, jnp.int32), kc)
            z = _cell_response(m_grid, FMT.e_max, gain_err=gerr)
            dnl = np.diff(z) / (z[1] - z[0] + 1e-12) - 1.0 if len(z) > 1 else [0]
            worst = max(worst, float(np.max(np.abs(dnl))))
        out[f"mismatch_kc{kc}_worst_dnl_lsb"] = worst
        emit(f"fig8/mismatch_kc{kc}", 0.0, f"worst_dnl_lsb={worst:.3f}")
    save_json("fig8", out)
    return out


if __name__ == "__main__":
    run()
