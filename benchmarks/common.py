"""Shared benchmark utilities: timing and CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the figure's scientific quantity, e.g. ENOB or fJ/Op) and returns a dict
for EXPERIMENTS.md."""
from __future__ import annotations

import json
import os
import time

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def time_call(fn, *args, n_iter: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")


def save_json(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path
