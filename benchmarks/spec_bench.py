"""Speculative-decode benchmark: accepted-tokens/step, TTLT speedup, and
the pJ/accepted-token energy verdict per arch family.

For each cache family the engine serves (attn / rglru / ssm / moe), a
single request decodes ``--tokens`` tokens sequentially and under
``serving.speculative.SpecDecoder``:

* ``selfdraft``  — the target config drafts for itself. Greedy
  acceptance is structurally total, so every counter
  (``accepted_tokens_per_step``, draft/verify/repair dispatch counts)
  and the bit-exactness boolean are pure functions of the config —
  benchmarks/compare.py gates them exactly.
* ``quantdraft`` — a fakequant-numerics drafter of the same weights: a
  genuinely different (cheaper) numerics path whose mispredictions
  exercise rollback + repair. Its acceptance rate depends on platform
  numerics, so only ``outputs_identical`` (the greedy exactness
  guarantee, which holds for ANY drafter) is exact-gated; the rate is
  reported.
* ``ttlt_ms``    — wall time from ``add_request`` to the last of
  ``--tokens`` tokens, sequential vs speculative (ratio-gated like the
  other wall-clock leaves; the speedup is the headline).
* ``energy``     — the analytic pJ/accepted-token account
  (``speculative.price_speculation``) of the *measured* selfdraft
  dispatch counters re-priced on the ``grmac`` CIM deployment of the
  same arch, digital drafter: sequential analog decode vs digital
  draft + analog chunk verify. Deterministic (seeded-MC ENOB pricing),
  so the boolean verdict is exact-gated. On today's constants the
  verdict is honest and negative — a digital drafter's conventional
  fJ/op dwarfs the GR-MAC path it saves, so speculation is a latency
  win that *costs* energy unless the drafter itself is an aggressive
  low-energy analog config (the ``site_overrides`` draft policy).

Run:  PYTHONPATH=src python -m benchmarks.spec_bench [--smoke]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig
from repro.serving.params import SamplingParams
from repro.serving.speculative import (SpecConfig, SpecDecoder,
                                       draft_arch_for, price_speculation)
from benchmarks.common import emit, save_json

ARCHS = [
    ("attn", "qwen2-1.5b"),
    ("rglru", "recurrentgemma-9b"),
    ("ssm", "mamba2-1.3b"),
    ("moe", "grok-1-314b"),
]
# shared by the --smoke CLI (refreshing the committed record) and
# benchmarks/compare.py's fresh run: the gate compares like for like
SMOKE_PARAMS = dict(prompt_len=8, tokens=16, k=4, slots=2, ctx=64,
                    record="spec_bench_smoke")


def _decode_all(eng, stepper, slot, prompt_len, tokens, max_steps=4096):
    for _ in range(max_steps):
        if not eng.active[slot] or \
                len(eng.tokens[slot]) - prompt_len >= tokens:
            break
        stepper()
    return eng.tokens[slot][prompt_len:][:tokens]


def bench_arch(name, prompt_len, tokens, k, slots, ctx, trials=3):
    arch = get_config(name).reduced()
    params = init_params(jax.random.PRNGKey(0), arch)
    prompt = [int(t) for t in
              np.random.RandomState(0).randint(1, arch.vocab_size,
                                               prompt_len)]
    cfg = ServeConfig(batch_slots=slots, max_ctx=ctx)
    sp = SamplingParams(max_tokens=tokens)

    def run(spec_draft, timed=False):
        best = float("inf")
        for _ in range(trials if timed else 1):
            eng = Engine(arch, params, cfg)
            dec = (SpecDecoder(eng, SpecConfig(k=k, draft=spec_draft))
                   if spec_draft is not None else None)
            step = (lambda: dec.step()) if dec else (lambda: eng.step())
            t0 = time.perf_counter()
            slot = eng.add_request(prompt, params=sp)
            toks = _decode_all(eng, step, slot, prompt_len, tokens)
            best = min(best, time.perf_counter() - t0)
            if not timed:
                break
        return toks, eng, best

    run(None)            # warm shared executables (compile excluded)
    run("self")
    ref, _, seq_ms = run(None, timed=True)
    got, eng_s, spec_ms = run("self", timed=True)
    st = eng_s.stats
    res = {
        "selfdraft": {
            "outputs_identical": got == ref,
            "accepted_tokens_per_step": st["spec_tokens"]
            / max(1, st["spec_steps"]),
            "spec_steps": st["spec_steps"],
            "spec_tokens": st["spec_tokens"],
            "draft_dispatches": st["draft_dispatches"],
            "verify_dispatches": st["verify_dispatches"],
            "repair_dispatches": st["repair_dispatches"],
        },
        "seq": {"ttlt_ms": seq_ms * 1e3},
        "spec": {"ttlt_ms": spec_ms * 1e3},
        "ttlt_speedup": seq_ms / spec_ms,
    }
    # the different-numerics drafter: exactness is guaranteed, the
    # acceptance rate is a measurement (platform-dependent numerics)
    qarch = arch.replace(cim=arch.cim.with_mode("fakequant"))
    gq, eng_q, _ = run(qarch)
    res["quantdraft"] = {
        "outputs_identical": gq == ref,
        "accepted_rate": eng_q.stats["spec_tokens"]
        / max(1, eng_q.stats["spec_steps"]) / k,
        "repair_dispatches_seen": eng_q.stats["repair_dispatches"],
    }
    # analytic energy verdict on the grmac deployment of this arch, from
    # the measured (deterministic) selfdraft counters
    cim = arch if arch.cim.enabled else arch.replace(
        cim=arch.cim.with_mode("grmac"))
    bucket = max(cfg.prefill_bucket_min, 1 << max(0, k - 1).bit_length())
    res["energy"] = price_speculation(
        cim, draft_arch_for(cim, "digital"), res["selfdraft"], bucket,
        n_cols=1 << 8)
    emit(f"spec/{name}", spec_ms * 1e6,
         f"accept={res['selfdraft']['accepted_tokens_per_step']:.2f}"
         f";speedup={res['ttlt_speedup']:.2f}"
         f";identical={int(res['selfdraft']['outputs_identical'])}")
    return res


def run(prompt_len=64, tokens=64, k=4, slots=4, ctx=256, archs=None,
        record="spec_bench"):
    out = {
        "params": {"prompt_len": prompt_len, "tokens": tokens, "k": k,
                   "slots": slots, "ctx": ctx},
        "archs": {},
    }
    for label, name in (archs or ARCHS):
        out["archs"][label] = {"config": name,
                               **bench_arch(name, prompt_len, tokens, k,
                                            slots, ctx)}
    ups = [a["ttlt_speedup"] for a in out["archs"].values()]
    out["ttlt_speedup_geomean"] = float(np.exp(np.mean(np.log(ups))))

    print(f"\n{'arch':<8} {'acc/step':>9} {'identical':>10} "
          f"{'ttlt seq(ms)':>13} {'ttlt spec(ms)':>14} {'speedup':>8} "
          f"{'spec pJ/tok':>12} {'seq pJ/tok':>11} {'e-win':>6}")
    for label, a in out["archs"].items():
        e = a["energy"]
        print(f"{label:<8} "
              f"{a['selfdraft']['accepted_tokens_per_step']:>9.2f} "
              f"{str(a['selfdraft']['outputs_identical']):>10} "
              f"{a['seq']['ttlt_ms']:>13.1f} {a['spec']['ttlt_ms']:>14.1f} "
              f"{a['ttlt_speedup']:>7.2f}x "
              f"{e['spec_pj_per_accepted_token']:>12.1f} "
              f"{e['seq_pj_per_token']:>11.1f} "
              f"{str(bool(e['energy_win'])):>6}")
    print(f"geomean TTLT speedup (spec vs sequential): "
          f"{out['ttlt_speedup_geomean']:.2f}x")
    save_json(record, out)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; refreshes the committed "
                         "spec_bench_smoke.json the CI gate compares")
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--k", type=int, default=4)
    args = ap.parse_args()
    if args.smoke:
        run(**SMOKE_PARAMS)
    else:
        run(tokens=args.tokens, k=args.k)


if __name__ == "__main__":
    main()
