"""Paper Fig. 10: required ADC ENOB vs input dynamic range (N_E,x sweep).

N_M,x = 2, weights FP4_E2M1 max-entropy, N_R = 32. Validates:
  C2  GR upper bound (uniform) >= 1.5 b below the conventional lower bound
  C3  >6 b reduction for Gaussian+outliers at N_E,x >= 3
  C8  GR ENOB stays below the ~10 b thermal crossover
"""
import time

import jax

from repro.core import adc as A
from repro.core import distributions as D
from repro.core import energy as E
from repro.core import formats as F
from benchmarks.common import emit, save_json


def run():
    key = jax.random.PRNGKey(0)
    table = {}
    for ne in [1, 2, 3, 4, 5]:
        fmt = F.FPFormat(ne, 2)
        for dname, dist in [
            ("uniform", D.uniform()),
            ("max_entropy", D.max_entropy(fmt)),
            ("gauss_outliers", D.gaussian_outliers()),
        ]:
            t0 = time.perf_counter()
            rc = A.required_enob(key, "conv", dist, fmt)
            ru = A.required_enob(key, "gr_unit", dist, fmt)
            rr = A.required_enob(key, "gr_row", dist, fmt)
            us = (time.perf_counter() - t0) / 3 * 1e6
            table[f"NE{ne}_{dname}"] = {
                "dr_db": fmt.dr_db, "conv": rc.enob, "gr_unit": ru.enob,
                "gr_row": rr.enob, "delta_unit": rc.enob - ru.enob,
            }
            emit(f"fig10/NE{ne}/{dname}", us,
                 f"conv={rc.enob:.2f};gr_unit={ru.enob:.2f}")
    ncross = E.TechParams().n_cross()
    claims = {
        "C2_upper_bound_1p5b": min(
            table[f"NE{ne}_uniform"]["delta_unit"] for ne in (2, 3, 4)),
        "C3_outlier_delta_NE3": table["NE3_gauss_outliers"]["delta_unit"],
        "C3_outlier_delta_NE4": table["NE4_gauss_outliers"]["delta_unit"],
        "C8_max_gr_enob": max(
            table[f"NE{ne}_uniform"]["gr_unit"] for ne in (2, 3, 4, 5)),
        "n_cross": ncross,
    }
    save_json("fig10", {"table": table, "claims": claims})
    return {"table": table, "claims": claims}


if __name__ == "__main__":
    run()
