"""Benchmark harness — one module per paper table/figure, plus the perf
suites (``kernel_bench``, ``serve_bench``) and the bench-regression gate
(``compare``, which diffs fresh --smoke runs against the committed
experiments/bench/*_smoke.json records).

Prints ``name,us_per_call,derived`` CSV rows and writes JSON payloads under
experiments/bench/ for EXPERIMENTS.md. Exit code is nonzero if any paper
claim check or bench-regression check fails.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        compare,
        e2e_energy,
        fig8_linearity,
        fig9_quant_noise,
        fig10_enob_vs_dr,
        fig11_enob_vs_precision,
        fig12_energy_dse,
        kernel_bench,
        mac_validation,
    )

    print("name,us_per_call,derived")
    failures = []

    r9 = fig9_quant_noise.run()
    for k, v in r9["observations"].items():
        if not v:
            failures.append(f"fig9:{k}")

    r10 = fig10_enob_vs_dr.run()
    c = r10["claims"]
    if c["C2_upper_bound_1p5b"] < 1.3:
        failures.append("fig10:C2")
    if c["C3_outlier_delta_NE3"] < 6.0:
        failures.append("fig10:C3")
    if c["C8_max_gr_enob"] > c["n_cross"]:
        failures.append("fig10:C8")

    r11 = fig11_enob_vs_precision.run()
    if not (0.7 < r11["slope_bits_per_mantissa_bit"] < 1.3):
        failures.append("fig11:linear-scaling")

    r12 = fig12_energy_dse.run()
    if not r12["fp6_e3m2"]["gr_native"]:
        failures.append("fig12:C6-fp6-native")
    if not r12["fp6_e3m2"]["conv_out_of_range"]:
        failures.append("fig12:C6-conv-range")
    if not (0.10 < r12["fp4"]["improvement"] < 0.60):
        failures.append("fig12:C6-fp4")
    if r12["dr_gain_bits_at_35db_iso_energy"] < 2:
        failures.append("fig12:C5-dr-gain")

    r8 = fig8_linearity.run()
    if r8["nominal_worst_inl_lsb"] > 1e-3:
        failures.append("fig8:nominal-linearity")
    if r8["mismatch_kc0.85_worst_dnl_lsb"] > 0.5:
        failures.append("fig8:mismatch-halflsb")

    mac_validation.run()
    # edge shape only: the claims harness stays fast (train_large_m takes
    # minutes) and must not overwrite the committed kernel_bench record
    kernel_bench.run(shapes={"edge_decode": kernel_bench._SHAPES["edge_decode"]},
                     record="kernel_bench_claims")
    e2e_energy.run()
    e2e_energy.run_pareto()   # per-site fronts (launch/summary --energy)

    # bench-regression gate: fresh --smoke runs vs the committed records
    # (see benchmarks/compare.py; CI runs the same check per push). The
    # threshold is machine-tolerant, like the CI lane's: the committed
    # baselines come from one reference machine, and a uniformly slower
    # box is not a regression — only order-of-magnitude rot should fail
    # the harness.
    failures += [f"bench-regression:{r}"
                 for r in compare.run(threshold=3.0, min_us=500.0)]

    if failures:
        print(f"\n[benchmarks] CLAIM CHECK FAILURES: {failures}",
              file=sys.stderr)
        raise SystemExit(1)
    print("\n[benchmarks] all paper-claim checks passed")


if __name__ == "__main__":
    main()
