"""Open-loop traffic serving benchmark: goodput vs arrival rate, per arch.

Extends ``serve_bench``'s fixed-batch TTFT measurement to the numbers a
capacity planner needs: seeded Poisson arrivals at swept request rates,
uniform prompt/output length distributions, served through the
continuous-batching ``Scheduler`` AND the naive blocking-admission
``StaticBatchScheduler`` baseline (classic static batching) on the same
traffic. Reports, per (arch, rate, mode):

* goodput — completed tokens per unit time counting only requests whose
  TTFT met the SLO (``goodput_tok_per_step`` in deterministic virtual
  dispatch-units; ``goodput_tok_s`` in wall time);
* TTFT / TPOT p50 and p99 (wall ms, machine-dependent; TTFT also in
  virtual units);
* queue depth (max / mean) and the dispatch / completion counts;
* decode-phase pJ/token of the arch under the GR-CIM path (ledger-
  derived, as in serve_bench — the benchmark engines themselves serve
  digital so the timing numbers measure the scheduler, not the
  simulator).

Determinism contract (the CI gate): scheduling runs on the virtual
``StepClock`` (one unit per compiled dispatch), so admission order,
chunk slicing, dispatch counts, completion counts and virtual-time SLO
attainment are pure functions of the seeded traffic — those leaves are
compared with EXACT equality by benchmarks/compare.py. Wall-clock
latency leaves get the usual ratio + noise-floor gates. Termination is
by ``max_new_tokens`` only (no EOS), so token *values* never influence
the schedule and the counts hold across machines and XLA versions.

The per-arch sweep derives a **saturation knee**: the first swept rate
where marginal goodput per marginal offered load drops below 0.5 (the
service saturates; queueing takes over). Above capacity the continuous
scheduler must sustain strictly higher goodput than static batching —
recorded as the exact-gated ``beats_static_above_capacity`` leaf.

The record also embeds the scheduler-layer invariant counters
(``repro.analysis.invariants.run_scheduler_invariants``): compile budget
and one-transfer-per-decode-step proven under interleaving, in the same
record the latency numbers come from.

Shared-prefix mode (``--shared-prefix``, the ``prefix_bench`` records):
seeded Zipf draws over a small system-prompt pool, served cache-off and
cache-on (``repro.serving.prefix_cache``) on identical traffic. The
cache must win TTFT p50 and prefill dispatches at *bit-identical*
outputs — those booleans, the hit/miss/insert/evict/bytes counters, and
the tokens-dispatched counts are exact-gated; the prefill pJ/output
token is ledger-priced from tokens actually dispatched, so the hit rate
surfaces as a measured energy reduction. Satellite cells rerun the same
traffic under shortest-prompt admission (anti-starvation age bound at
the SLO) and under the closed-loop fixed-concurrency client model
(``run_closed_loop``).

Run:  PYTHONPATH=src python -m benchmarks.traffic_bench [--smoke]
          [--shared-prefix]
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.core import costs
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig
from repro.serving.scheduler import (
    Scheduler,
    SchedulerConfig,
    StaticBatchScheduler,
    StepClock,
    run_closed_loop,
    run_open_loop,
    synth_shared_prefix_traffic,
    synth_traffic,
)
from benchmarks.common import emit, save_json

# attention KV and SSM recurrent-state cache families: the two extremes
# of per-slot state the scheduler juggles (serve_bench covers all four
# families; the traffic sweep keeps two so the rate grid stays wide)
ARCHS = [
    ("attn", "qwen2-1.5b"),
    ("ssm", "mamba2-1.3b"),
]
# offered load as fractions of the estimated saturation rate: two below,
# at, and two above capacity — enough points to localize the knee
RATE_FRACS = (0.25, 0.5, 1.0, 2.0, 4.0)

SMOKE_PARAMS = dict(n_requests=10, slots=2, ctx=64, prompt_len=(4, 12),
                    out_len=(2, 12), budget=8, slo_ttft=40.0,
                    preempt_age=40.0, rate_fracs=(0.5, 1.0, 2.5),
                    record="traffic_bench_smoke")

# shared-prefix mode (run_shared_prefix): Zipf draws over a small
# system-prompt pool, cache-on vs cache-off on the same traffic.
# prefix_len is a multiple of prefill_bucket_min (8) so the shared part
# is a cacheable chunk boundary; rate_frac 1.5x capacity queues enough
# that the saved prefill dispatches show up in TTFT, not just counters.
SHARED_SMOKE_PARAMS = dict(n_requests=10, slots=2, ctx=64, n_prefixes=3,
                           prefix_len=16, zipf_s=1.1, user_len=(3, 10),
                           out_len=(2, 8), budget=8, slo_ttft=40.0,
                           rate_frac=1.5, cache_bytes=1 << 24,
                           concurrency=4, record="prefix_bench_smoke")


def _capacity_est(slots, out_len) -> float:
    """Crude saturation-rate estimate (requests per dispatch-unit): each
    request holds a slot for about its decode-token count of dispatch
    units plus ~2 prefill chunks, and ``slots`` lanes share every decode
    dispatch."""
    mean_out = (out_len[0] + out_len[1]) / 2.0
    return slots / (mean_out + 2.0)


def _warm(arch, params, slots, ctx, prompt_len, budget):
    """Populate the shared per-arch executable caches for every bucket
    the sweep can touch (budget-truncated chunks pad to powers of two up
    to the longest prompt's bucket), so measured latency is the serving
    steady state, not compile time."""
    cfg = ServeConfig(batch_slots=slots, max_ctx=ctx)
    eng = Engine(arch, params, cfg)
    # every power-of-two bucket up to the longest prompt's: the static
    # baseline dispatches whole prompts (any bucket in that range), the
    # budgeted scheduler only chunks <= budget, but both share the caches
    lens, b = set(), cfg.prefill_bucket_min
    while True:
        lens.add(min(b, ctx - 2))
        if b >= prompt_len[1]:
            break
        b *= 2
    for n in sorted(lens):
        eng.add_request([1] * n)
        eng.step()
        for s in range(slots):
            eng.release_slot(s)


def bench_arch(name, *, n_requests, slots, ctx, prompt_len, out_len,
               budget, slo_ttft, preempt_age, rate_fracs, seed=0):
    arch = get_config(name).reduced()
    params = init_params(jax.random.PRNGKey(0), arch)
    _warm(arch, params, slots, ctx, prompt_len, budget)
    cap = _capacity_est(slots, out_len)

    res = {"capacity_est_req_per_step": cap, "rates": {}}
    sweep = []   # (frac, offered_tok_per_step, sched_goodput)
    for frac in rate_fracs:
        rate = frac * cap
        traffic = synth_traffic(n_requests, rate, seed=seed,
                                vocab_size=arch.vocab_size,
                                prompt_len=prompt_len, out_len=out_len)
        total_tokens = sum(t.max_new_tokens for t in traffic)
        offered = rate * total_tokens / n_requests
        cell = {"rate_req_per_step": rate,
                "offered_tok_per_step": offered}
        for mode, make in (
                # preempt_age at the TTFT SLO: a queue-head request aging
                # past it evicts the youngest running request (LIFO
                # victim), so preemption shows up in the goodput curves
                # above capacity — the ``preempted`` count is exact-gated
                # like the rest of the scheduling counters. Tighter ages
                # thrash under sustained overload (victim recompute beats
                # the rescued request's odds of still making its SLO) and
                # hand the goodput win back to static batching
                ("scheduler", lambda e, c: Scheduler(
                    e, SchedulerConfig(prefill_token_budget=budget,
                                       preempt_age=preempt_age),
                    clock=c.now)),
                ("static", lambda e, c: StaticBatchScheduler(
                    e, clock=c.now))):
            clock = StepClock()
            eng = Engine(arch, params,
                         ServeConfig(batch_slots=slots, max_ctx=ctx))
            sched = make(eng, clock)
            t0 = time.perf_counter()
            run_open_loop(sched, traffic, tick=clock.tick)
            wall = time.perf_counter() - t0
            m = sched.metrics(slo_ttft=slo_ttft)
            m.pop("pj_per_token"), m.pop("energy_pj")  # CIM off: priced below
            m["run_wall_s"] = wall
            cell[mode] = m
            emit(f"traffic/{name}/{frac}x/{mode}", wall * 1e6,
                 f"goodput_step={m['goodput_tok_per_step']:.3f}"
                 f";in_slo={m['completed_in_slo']}/{m['completed']}")
        cell["goodput_ratio_vs_static"] = (
            cell["scheduler"]["goodput_tok_per_step"]
            / max(cell["static"]["goodput_tok_per_step"], 1e-12))
        res["rates"][f"{frac}x"] = cell
        sweep.append((frac, offered,
                      cell["scheduler"]["goodput_tok_per_step"]))

    # saturation knee: first rate whose marginal goodput per marginal
    # offered load drops below 0.5 — service saturated, queueing onward
    knee = None
    for (f0, o0, g0), (f1, o1, g1) in zip(sweep, sweep[1:]):
        if (g1 - g0) / max(o1 - o0, 1e-12) < 0.5:
            knee = f1
            break
    res["knee_rate_frac"] = knee
    res["beats_static_above_capacity"] = all(
        c["goodput_ratio_vs_static"] > 1.0
        for label, c in res["rates"].items()
        if float(label[:-1]) > 1.0)

    # deployment energy next to the traffic curves (ledger-derived, as in
    # serve_bench: the engines above serve digital, the CIM path is priced
    # on the shape-only trace)
    cim_arch = arch if arch.cim.enabled else arch.replace(
        cim=arch.cim.with_mode("grmac"))
    res["pj_per_token"] = costs.price_ledger(
        costs.trace_decode(cim_arch), 1, n_cols=1 << 8)["pj_per_token"]
    return res


def run(n_requests=32, slots=4, ctx=256, prompt_len=(8, 48),
        out_len=(4, 32), budget=16, slo_ttft=80.0, preempt_age=80.0,
        rate_fracs=RATE_FRACS, archs=None, record="traffic_bench", seed=0):
    from repro.analysis.invariants import run_scheduler_invariants

    out = {
        "params": {"n_requests": n_requests, "slots": slots, "ctx": ctx,
                   "prompt_len": list(prompt_len),
                   "out_len": list(out_len), "budget": budget,
                   "slo_ttft_steps": slo_ttft,
                   "preempt_age_steps": preempt_age,
                   "rate_fracs": list(rate_fracs), "seed": seed},
        "archs": {},
    }
    for label, name in (archs or ARCHS):
        out["archs"][label] = {
            "config": name,
            **bench_arch(name, n_requests=n_requests, slots=slots, ctx=ctx,
                         prompt_len=prompt_len, out_len=out_len,
                         budget=budget, slo_ttft=slo_ttft,
                         preempt_age=preempt_age, rate_fracs=rate_fracs,
                         seed=seed)}
    # the compile-budget / one-transfer invariants, proven under the
    # instrumented scheduler, in the same record the latency comes from
    out["invariants"] = run_scheduler_invariants(("qwen2-1.5b",))

    print(f"\n{'arch':<6} {'rate':>6} {'offered':>8} "
          f"{'goodput sched':>14} {'goodput static':>15} {'ratio':>6} "
          f"{'in-SLO':>7} {'ttft p99 ms':>12} {'qmax':>5}")
    for label, a in out["archs"].items():
        for rl, c in a["rates"].items():
            s, st = c["scheduler"], c["static"]
            print(f"{label:<6} {rl:>6} {c['offered_tok_per_step']:>8.2f} "
                  f"{s['goodput_tok_per_step']:>14.3f} "
                  f"{st['goodput_tok_per_step']:>15.3f} "
                  f"{c['goodput_ratio_vs_static']:>6.2f} "
                  f"{s['completed_in_slo']:>3}/{s['completed']:<3} "
                  f"{s['ttft_p99_ms']:>12.1f} {s['queue_depth_max']:>5}")
        print(f"{label:<6} knee at {a['knee_rate_frac']}x capacity; "
              f"beats static above capacity: "
              f"{a['beats_static_above_capacity']}; "
              f"{a['pj_per_token']:.1f} pJ/token (CIM decode)")
    save_json(record, out)
    return out


def _sched_run(arch, params, traffic, *, slots, ctx, budget, slo_ttft,
               cache_bytes=None, admission="fifo", age_bound=None,
               closed_concurrency=None):
    """One scheduler run over ``traffic`` on a fresh engine + StepClock;
    returns (metrics, {rid: generated tokens}). ``closed_concurrency``
    switches from the open-loop Poisson driver to the fixed-concurrency
    closed-loop one."""
    clock = StepClock()
    eng = Engine(arch, params,
                 ServeConfig(batch_slots=slots, max_ctx=ctx,
                             prefix_cache_bytes=cache_bytes))
    sched = Scheduler(eng, SchedulerConfig(prefill_token_budget=budget,
                                           admission=admission,
                                           admission_age_bound=age_bound),
                      clock=clock.now)
    t0 = time.perf_counter()
    if closed_concurrency is None:
        run_open_loop(sched, traffic, tick=clock.tick)
    else:
        run_closed_loop(sched, traffic, concurrency=closed_concurrency,
                        tick=clock.tick)
    wall = time.perf_counter() - t0
    m = sched.metrics(slo_ttft=slo_ttft)
    m.pop("pj_per_token"), m.pop("energy_pj")  # CIM off: priced separately
    m["run_wall_s"] = wall
    outs = {r.rid: list(r.generated) for r in sched.finished}
    return m, outs


def bench_shared_prefix_arch(name, *, n_requests, slots, ctx, n_prefixes,
                             prefix_len, zipf_s, user_len, out_len, budget,
                             slo_ttft, rate_frac, cache_bytes, concurrency,
                             seed=0):
    arch = get_config(name).reduced()
    params = init_params(jax.random.PRNGKey(0), arch)
    plen = (prefix_len + user_len[0], prefix_len + user_len[1])
    _warm(arch, params, slots, ctx, plen, budget)
    cap = _capacity_est(slots, out_len)
    rate = rate_frac * cap
    traffic = synth_shared_prefix_traffic(
        n_requests, rate, seed=seed, vocab_size=arch.vocab_size,
        n_prefixes=n_prefixes, prefix_len=prefix_len, zipf_s=zipf_s,
        user_len=user_len, out_len=out_len)

    res = {"rate_req_per_step": rate, "modes": {}}
    common = dict(slots=slots, ctx=ctx, budget=budget, slo_ttft=slo_ttft)
    outs = {}
    for mode, cb in (("cache_off", None), ("cache_on", cache_bytes)):
        m, outs[mode] = _sched_run(arch, params, traffic,
                                   cache_bytes=cb, **common)
        res["modes"][mode] = m
        emit(f"prefix/{name}/{mode}", m["run_wall_s"] * 1e6,
             f"ttft_p50_steps={m['ttft_p50_steps']:.1f}"
             f";dispatches={m['prefill_dispatches']}"
             f";hits={m.get('prefix_hits', 0)}")

    # prefill energy at the CIM operating point, priced by prompt tokens
    # actually dispatched: hits convert straight into analog MAC + ADC
    # work not done. Per-prefill-token price at the budget-sized bucket
    # (the chunk the scheduler dispatches), normalized per output token.
    cim_arch = arch if arch.cim.enabled else arch.replace(
        cim=arch.cim.with_mode("grmac"))
    price = costs.price_ledger(
        costs.trace_prefill(cim_arch, bucket=budget), budget,
        n_cols=1 << 8)["pj_per_token"]
    for m in res["modes"].values():
        m["prefill_pj_per_output_token"] = (
            price * m["prefill_tokens_dispatched"]
            / max(m["generated_tokens"], 1))
    off, on = res["modes"]["cache_off"], res["modes"]["cache_on"]

    # the acceptance leaves, all deterministic under StepClock and
    # exact-gated by compare.py: the hit streams must be bit-identical
    # to cold prefill AND strictly cheaper to serve
    res["outputs_identical"] = outs["cache_off"] == outs["cache_on"]
    res["cache_wins_ttft"] = on["ttft_p50_steps"] < off["ttft_p50_steps"]
    res["cache_wins_dispatches"] = (on["prefill_dispatches"]
                                    < off["prefill_dispatches"])
    res["prefill_pj_reduced"] = (on["prefill_pj_per_output_token"]
                                 < off["prefill_pj_per_output_token"])
    res["prefill_pj_reduction_pct"] = 100.0 * (
        1.0 - on["prefill_pj_per_output_token"]
        / max(off["prefill_pj_per_output_token"], 1e-12))

    # satellite cells on the same traffic, cache on: shortest-prompt
    # admission (anti-starvation bound at the SLO) and the closed-loop
    # fixed-concurrency client model — their scheduling counts ride the
    # same exact gates
    m, _ = _sched_run(arch, params, traffic, cache_bytes=cache_bytes,
                      admission="shortest_prompt", age_bound=slo_ttft,
                      **common)
    res["modes"]["shortest_prompt"] = m
    m, _ = _sched_run(arch, params, traffic, cache_bytes=cache_bytes,
                      closed_concurrency=concurrency, **common)
    m["concurrency"] = concurrency
    res["modes"]["closed_loop"] = m
    return res


def run_shared_prefix(n_requests=32, slots=4, ctx=256, n_prefixes=4,
                      prefix_len=32, zipf_s=1.1, user_len=(4, 24),
                      out_len=(4, 16), budget=8, slo_ttft=80.0,
                      rate_frac=1.5, cache_bytes=1 << 26, concurrency=8,
                      archs=None, record="prefix_bench", seed=0):
    """Shared-prefix traffic sweep: cache-on vs cache-off on identical
    seeded Zipf system-prompt traffic, per arch family, plus the
    shortest-prompt-admission and closed-loop satellite cells. See the
    module docstring's determinism contract — every count and derived
    win/loss boolean here is exact-gated."""
    from repro.analysis.invariants import run_prefix_invariants

    out = {
        "params": {"n_requests": n_requests, "slots": slots, "ctx": ctx,
                   "n_prefixes": n_prefixes, "prefix_len": prefix_len,
                   "zipf_s": zipf_s, "user_len": list(user_len),
                   "out_len": list(out_len), "budget": budget,
                   "slo_ttft_steps": slo_ttft, "rate_frac": rate_frac,
                   "cache_bytes": cache_bytes, "concurrency": concurrency,
                   "seed": seed},
        "archs": {},
    }
    for label, name in (archs or ARCHS):
        out["archs"][label] = {
            "config": name,
            **bench_shared_prefix_arch(
                name, n_requests=n_requests, slots=slots, ctx=ctx,
                n_prefixes=n_prefixes, prefix_len=prefix_len,
                zipf_s=zipf_s, user_len=user_len, out_len=out_len,
                budget=budget, slo_ttft=slo_ttft, rate_frac=rate_frac,
                cache_bytes=cache_bytes, concurrency=concurrency,
                seed=seed)}
    # compile/transfer invariants re-proven under the hit-heavy trace,
    # in the same record the cache wins come from
    out["invariants"] = run_prefix_invariants(("qwen2-1.5b",))

    print(f"\n{'arch':<6} {'mode':<16} {'ttft p50':>9} {'dispatch':>9} "
          f"{'pfill tok':>10} {'saved':>6} {'hits':>5} {'pJ/out-tok':>11}")
    for label, a in out["archs"].items():
        for mode, m in a["modes"].items():
            print(f"{label:<6} {mode:<16} {m['ttft_p50_steps']:>9.1f} "
                  f"{m['prefill_dispatches']:>9} "
                  f"{m['prefill_tokens_dispatched']:>10} "
                  f"{m['prefill_tokens_saved']:>6} "
                  f"{m.get('prefix_hits', 0):>5} "
                  f"{m['prefill_pj_per_output_token'] if 'prefill_pj_per_output_token' in m else float('nan'):>11.1f}")
        print(f"{label:<6} outputs identical: {a['outputs_identical']}; "
              f"cache wins ttft/dispatches: {a['cache_wins_ttft']}/"
              f"{a['cache_wins_dispatches']}; prefill pJ -"
              f"{a['prefill_pj_reduction_pct']:.1f}%")
    save_json(record, out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=256)
    ap.add_argument("--budget", type=int, default=16,
                    help="prefill token budget per scheduler step")
    ap.add_argument("--slo-ttft", type=float, default=80.0,
                    help="TTFT SLO in virtual dispatch-units")
    ap.add_argument("--preempt-age", type=float, default=80.0,
                    help="queue-head age (virtual units) that triggers "
                         "LIFO preemption of a running request")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the CI bench lane")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="run the shared-prefix cache-on/cache-off mode "
                         "(prefix_bench record) instead of the rate sweep")
    args = ap.parse_args()
    if args.shared_prefix:
        # separate records: smoke runs must not clobber the committed
        # full-size jsons
        run_shared_prefix(**SHARED_SMOKE_PARAMS) if args.smoke \
            else run_shared_prefix()
    elif args.smoke:
        run(**SMOKE_PARAMS)
    else:
        run(n_requests=args.requests, slots=args.slots, ctx=args.ctx,
            budget=args.budget, slo_ttft=args.slo_ttft,
            preempt_age=args.preempt_age)
