"""Serving-path benchmark: TTFT and decode throughput per arch × prefill mode.

For each architecture family the engine serves (full attention, RG-LRU,
Mamba2 SSM, MoE — their cache-merge semantics all differ, so all four are
exercised), measures on the reduced config:

* ``ttft_ms``    — wall time from ``add_request`` through the first decode
  step (compile cost excluded: a warmup engine populates the shared
  per-arch executable caches first, which is the serving steady state).
* ``decode_tok_s`` — steady-state decode throughput over ``--steps`` steps.
* ``prefill_dispatches`` — compiled dispatches the prefill issued; the
  bucketed path must stay at ``ceil(len / bucket_max)`` vs one per token.

Both ``prefill_mode="token"`` (the legacy baseline) and ``"bucketed"`` (the
chunked path) run, and the headline ``ttft_speedup`` ratios are recorded to
``experiments/bench/serve_bench.json`` alongside the geomean.

Run:  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import costs
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig
from benchmarks.common import emit, save_json

ARCHS = [
    ("attn", "qwen2-1.5b"),
    ("rglru", "recurrentgemma-9b"),
    ("ssm", "mamba2-1.3b"),
    ("moe", "grok-1-314b"),
]
_MODES = ("token", "bucketed")
# the one smoke configuration: shared by the --smoke CLI (which refreshes
# the committed serve_bench_smoke.json) and benchmarks/compare.py's fresh
# run, so the regression gate always compares like-for-like configs
SMOKE_PARAMS = dict(prompt_len=12, steps=4, slots=2, ctx=64,
                    record="serve_bench_smoke")


def bench_arch(name, prompt_len, steps, slots, ctx, trials=3):
    arch = get_config(name).reduced()
    params = init_params(jax.random.PRNGKey(0), arch)
    prompt = [int(t) for t in
              np.random.RandomState(0).randint(1, arch.vocab_size, prompt_len)]
    res = {}
    for mode in _MODES:
        cfg = ServeConfig(batch_slots=slots, max_ctx=ctx, prefill_mode=mode)
        # warm the shared per-arch executables (prefill buckets + decode)
        warm = Engine(arch, params, cfg)
        warm.add_request(prompt)
        warm.step()

        # best of ``trials`` fresh engines: a single-shot TTFT sample is
        # dominated by scheduler jitter at small sizes, which made the
        # compare.py regression gate flap — the best observed time is the
        # stable "what the code can do" figure of merit
        ttft, tok_s = float("inf"), 0.0
        for _ in range(trials):
            eng = Engine(arch, params, cfg)
            t0 = time.perf_counter()
            slot = eng.add_request(prompt)
            first = eng.step()
            ttft = min(ttft, time.perf_counter() - t0)
            assert slot in first
            t0 = time.perf_counter()
            for _ in range(steps):
                eng.step()
            tok_s = max(tok_s, steps / (time.perf_counter() - t0))
        res[mode] = {
            "ttft_ms": ttft * 1e3,
            "prefill_dispatches": eng.stats["prefill_dispatches"],
            "decode_tok_s": tok_s,
        }
        emit(f"serve/{name}/{mode}", ttft * 1e6,
             f"tok_s={tok_s:.0f}"
             f";dispatches={eng.stats['prefill_dispatches']}")
    res["ttft_speedup"] = res["token"]["ttft_ms"] / res["bucketed"]["ttft_ms"]
    # deployment energy next to the latency figures: decode-phase pJ per
    # generated token of this arch under the GR-CIM path (ledger-derived;
    # the benchmark engines themselves serve with CIM off, so the timing
    # numbers measure the digital hot path, not the simulator)
    cim_arch = arch if arch.cim.enabled else arch.replace(
        cim=arch.cim.with_mode("grmac"))
    res["pj_per_token"] = costs.price_ledger(
        costs.trace_decode(cim_arch), 1, n_cols=1 << 8)["pj_per_token"]
    return res


def run(prompt_len=64, steps=32, slots=4, ctx=256, archs=None,
        record="serve_bench"):
    out = {
        "params": {"prompt_len": prompt_len, "steps": steps, "slots": slots,
                   "ctx": ctx},
        "archs": {},
    }
    for label, name in (archs or ARCHS):
        out["archs"][label] = {"config": name,
                               **bench_arch(name, prompt_len, steps, slots,
                                            ctx)}
    ups = [a["ttft_speedup"] for a in out["archs"].values()]
    out["ttft_speedup_geomean"] = float(np.exp(np.mean(np.log(ups))))

    print(f"\n{'arch':<8} {'ttft token(ms)':>15} {'ttft bucketed(ms)':>18} "
          f"{'speedup':>8} {'dispatches':>11} {'tok/s':>8} {'pJ/tok':>10}")
    for label, a in out["archs"].items():
        print(f"{label:<8} {a['token']['ttft_ms']:>15.1f} "
              f"{a['bucketed']['ttft_ms']:>18.1f} "
              f"{a['ttft_speedup']:>7.1f}x "
              f"{a['token']['prefill_dispatches']:>4}->"
              f"{a['bucketed']['prefill_dispatches']:<5} "
              f"{a['bucketed']['decode_tok_s']:>8.0f} "
              f"{a['pj_per_token']:>10.1f}")
    print(f"geomean TTFT speedup (bucketed vs token): "
          f"{out['ttft_speedup_geomean']:.1f}x")
    save_json(record, out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the CI bench lane")
    args = ap.parse_args()
    if args.smoke:
        # separate record: a smoke run must not clobber the committed
        # full-size serve_bench.json the ROADMAP cites
        run(**SMOKE_PARAMS)
    else:
        run(prompt_len=args.prompt_len, steps=args.steps, slots=args.slots,
            ctx=args.ctx)
