"""Batched serving with the GR-CIM inference path + per-token energy report.

The engine prefills each prompt through the chunked bucketed path — the
whole prompt is padded to a power-of-two bucket and written into the KV /
recurrent caches at per-slot offsets in ONE compiled dispatch (vs one
dispatch per token before), and decode samples on device, so each ``step``
moves exactly one small int32 array back to the host.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig, energy_report


def main():
    arch = get_config("paper-cim-120m").replace(
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, d_head=64, d_ff=1024,
        vocab_size=2048)
    params = init_params(jax.random.PRNGKey(0), arch)
    eng = Engine(arch, params, ServeConfig(batch_slots=4, max_ctx=128))

    s0 = eng.add_request([1, 2, 3, 4, 5])
    s1 = eng.add_request([10, 20, 30])
    print(f"prefilled slots {s0}, {s1} in "
          f"{eng.stats['prefill_dispatches']} compiled dispatches "
          f"(token-by-token would have used 8); decoding 16 steps...")
    for step in range(16):
        out = eng.step()   # on-device greedy sampling: one int32/slot back
        if step % 4 == 0:
            # the typed per-request stream (StepResult.outputs) — one
            # RequestOutput per live request, with finish reasons
            print(f"  step {step}: " + ", ".join(
                f"slot {o.slot}: {o.tokens}" for o in out.outputs))
    print("generated:", {s: eng.tokens[s][-8:] for s in (s0, s1)})

    rep = energy_report(arch)   # ledger-derived: traced from the model
    print(f"CIM energy: {rep['fj_per_op']:.1f} fJ/Op -> "
          f"{rep['pj_per_token']/1e3:.2f} nJ/token decoded "
          f"(conventional CIM: {rep['conventional_fj_per_op']:.1f} fJ/Op)")
    for site, s in rep["sites"].items():
        print(f"  {site:10s} {s['granularity']:5s} "
              f"{s['pj_per_token']:10.1f} pJ/token")


if __name__ == "__main__":
    main()
