"""Quickstart: the paper's contribution in 30 lines.

1. Quantize activations/weights to low-bit FP formats.
2. Run a matmul through the GR-MAC simulation (row normalization, 8 b ADC).
3. Compare against the conventional FP->INT CIM at the same ADC resolution.
4. Price both designs with the paper's 28 nm energy model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import FP4_E2M1, FP6_E3M2
from repro.core.adc import required_enob
from repro.core.cim_config import CIMConfig
from repro.core.distributions import gaussian_outliers, uniform
from repro.core.dse import evaluate_point
from repro.kernels.ops import cim_matmul

key = jax.random.PRNGKey(0)
kx, kw = jax.random.split(key)
x = jax.random.normal(kx, (64, 512)) * 0.1          # LLM-ish activations
w = jax.random.normal(kw, (512, 256)) * 0.05

exact = x @ w
for gran in ["row", "unit"]:
    cfg = CIMConfig(mode="grmac", granularity=gran,
                    fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32)
    out = cim_matmul(x, w, cfg)
    rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
    print(f"GR-MAC [{gran:4s}]  rel err vs exact fp32: {rel:.4f}")

# ADC requirement: the GR-MAC bound is data-INVARIANT (paper contribution 1)
for dist in [uniform(), gaussian_outliers()]:
    rc = required_enob(key, "conv", dist, FP6_E3M2)
    ru = required_enob(key, "gr_unit", dist, FP6_E3M2)
    print(f"{dist.name:24s} ADC: conv {rc.enob:5.2f} b -> GR {ru.enob:5.2f} b"
          f"  (saves {rc.enob - ru.enob:.2f} b)")

# energy at the FP6_E3M2 design point (paper Fig. 12)
pt = evaluate_point(key, FP6_E3M2, n_cols=1 << 12)
print(f"energy/Op: conventional {pt.conv.total:8.1f} fJ "
      f"(out of practical range) | GR-CIM {pt.gr.total:5.1f} fJ [{pt.gr_arch}]")
