"""Per-site design-space exploration: trace a real model's matmul sites,
sweep the (format × n_r × granularity) candidate grid per site against an
accuracy budget, and print the Pareto fronts plus the ready-to-apply
``site_overrides`` deployment (``core.dse.explore_pareto``).

Run:  PYTHONPATH=src python examples/site_pareto.py --arch paper-cim-120m \
          --budget 35 [--phase decode]
"""
import argparse

from repro.configs import get_config, list_configs
from repro.core import costs, dse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-cim-120m",
                    choices=list_configs())
    ap.add_argument("--budget", type=float,
                    default=dse.PAPER_SQNR_STANDARD_DB,
                    help="per-site accuracy floor in SQNR dB "
                         "(paper standard: 35)")
    ap.add_argument("--phase", default="decode",
                    choices=("decode", "prefill", "train"))
    ap.add_argument("--n-cols", type=int, default=1 << 10,
                    help="Monte-Carlo columns per ENOB solve")
    args = ap.parse_args()

    arch = get_config(args.arch)
    if not arch.cim.enabled:
        arch = arch.replace(cim=arch.cim.with_mode("grmac"))
    trace = {"decode": costs.trace_decode,
             "prefill": costs.trace_prefill,
             "train": costs.trace_train}[args.phase]
    ledger = trace(arch)

    res = dse.explore_pareto(
        arch.cim, ledger,
        budget=dse.SiteBudget(min_sqnr_db=args.budget),
        n_cols=args.n_cols)

    print(f"{args.arch} · {args.phase} · budget {args.budget:.1f} dB")
    for site, info in sorted(res["sites"].items()):
        if "front" not in info:
            print(f"  {site:12s} digital ({info['ops']:.3g} Ops)")
            continue
        front = " -> ".join(
            f"{c['fmt_x']}/n{c['n_r']}/{c['granularity']}"
            f"[{c['fj_per_op']:.1f} fJ/Op @ {c['sqnr_db']:.1f} dB]"
            for c in info["front"])
        chosen = info["chosen"]
        label = chosen if isinstance(chosen, str) else \
            f"{chosen['fmt_x']}/n{chosen['n_r']}/{chosen['granularity']}"
        print(f"  {site:12s} front: {front}\n"
              f"  {'':12s} chosen: {label} "
              f"(base {info['base']['fmt_x']}/n{info['base']['n_r']}/"
              f"{info['base']['granularity']})")
    print("deployment front (total pJ vs weakest-site SQNR):")
    for p in res["front"]:
        print(f"  >= {p['sqnr_db']:5.1f} dB : {p['pj']:.3g} pJ")
    print(f"ledger energy: chosen {res['pj']:.3g} pJ "
          f"vs base {res['base_pj']:.3g} pJ")
    print("ready-to-apply site_overrides:")
    for site, ov in sorted(res["site_overrides"].items()):
        print(f"  {site}: {ov if isinstance(ov, str) else ov.as_dict()}")
    # the emitted mapping applies in one call — this config now *runs*
    # the chosen mixed deployment (and core.costs prices it identically)
    cfg = arch.cim.with_site_overrides(res["site_overrides"])
    assert cfg == res["config"]


if __name__ == "__main__":
    main()
