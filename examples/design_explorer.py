"""Interactive-ish design-space exploration: pick a workload's dynamic-range
and precision needs, get the energy-optimal CIM configuration (the paper's
Fig. 12 as a tool). For the per-*site* sweep over a traced model (formats ×
n_r × granularity with accuracy budgets and Pareto fronts), see
``examples/site_pareto.py``.

Run:  PYTHONPATH=src python examples/design_explorer.py --sqnr 35 --dr 60
"""
import argparse
import math

import jax

from repro.core import dse as S
from repro.core import formats as F


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sqnr", type=float, default=35.0, help="target SQNR dB")
    ap.add_argument("--dr", type=float, default=60.0, help="target DR dB")
    ap.add_argument("--n_r", type=int, default=32)
    args = ap.parse_args()

    nm = max(1, math.ceil((args.sqnr - 10.79) / 6.02))
    key = jax.random.PRNGKey(0)
    print(f"target: SQNR>={args.sqnr} dB (N_M={nm}), DR>={args.dr} dB")
    best = None
    for ne in (1, 2, 3, 4):
        fmt = F.FPFormat(ne, nm)
        dr_db, sqnr_db = S.spec_of_format(fmt)
        if dr_db < args.dr:
            continue
        pt = S.evaluate_point(key, fmt, n_r=args.n_r, n_cols=1 << 12)
        for label, e in [("conventional", pt.conv), (f"GR[{pt.gr_arch}]", pt.gr)]:
            if e is None:
                continue
            print(f"  {fmt.name}: {label:16s} {e.total:9.1f} fJ/Op "
                  f"(ADC {pt.enob_conv if label=='conventional' else pt.enob_gr:.1f} b)"
                  f" breakdown={ {k: round(v,1) for k,v in e.as_dict().items()} }")
            if e.total and (best is None or e.total < best[0]):
                best = (e.total, fmt.name, label)
    if best:
        print(f"==> optimal: {best[1]} via {best[2]} at {best[0]:.1f} fJ/Op")
    else:
        print("==> no feasible design point (DR beyond the gain-ranging span;"
              " add global normalization)")


if __name__ == "__main__":
    main()
