"""End-to-end driver: train a ~120M-param LM for a few hundred steps with
the GR-CIM fake-quant path on (QAT), checkpointing + resume included.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--cim grmac]
(~120M params on CPU is slow; --small trains a 15M variant quickly.)
"""
import argparse

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.training.optimizer import OptimizerConfig
from repro.training.trainer import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--cim", default="fakequant",
                    choices=["off", "fakequant", "grmac"])
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    arch = get_config("paper-cim-120m")
    arch = arch.replace(cim=arch.cim.with_mode(args.cim))
    if args.small:
        arch = arch.replace(n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, d_head=64,
                            d_ff=1024, vocab_size=2048)
    dcfg = DataConfig(global_batch=8, seq_len=256,
                      vocab_size=arch.vocab_size, seed=0)
    tcfg = TrainConfig(
        steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=100, log_every=10,
        opt=OptimizerConfig(lr=3e-4, warmup_steps=30, total_steps=args.steps))
    metrics = train(arch, tcfg, SyntheticLM(dcfg))
    print("final:", metrics)


if __name__ == "__main__":
    main()
