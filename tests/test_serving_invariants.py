"""Hot-path invariant harness (``repro.analysis.invariants``): the compile
budget (one trace per (arch, bucket)/(arch, sample) executable) and the
one-device-to-host-transfer-per-decode-step rule hold on a real serve
script — and the harness genuinely fails when either regresses."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.invariants import (
    InstrumentedEngine,
    InvariantViolation,
    _drive,
    run_invariants,
)
from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import ServeConfig


def _engine(batch_slots=2, max_ctx=64):
    arch = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), arch)
    return arch, params, ServeConfig(batch_slots=batch_slots,
                                     max_ctx=max_ctx)


def test_serve_script_holds_both_invariants():
    rep = _drive("qwen2-1.5b")
    assert rep["compiles"] == 2              # 1 prefill + 1 decode trace
    assert rep["fetches"] == 2 + rep["steps"]
    assert rep["steps"] > 0


def test_run_invariants_reports_clean():
    out = run_invariants(configs=("qwen2-1.5b",))
    assert out["violations"] == 0
    assert out["failed"] == []
    assert out["configs"]["qwen2-1.5b"]["compiles"] == 2


def test_retrace_is_detected():
    """A jit key whose input shapes drift is the PR-1 recompile bug; the
    counting jit sees the second trace and check() refuses it."""
    arch, params, cfg = _engine(batch_slots=1, max_ctx=16)
    eng = InstrumentedEngine(arch, params, cfg)
    f = eng._counting_jit("decode[probe]", lambda x: x * 2)
    f(jnp.zeros((2,)))
    f(jnp.zeros((3,)))                       # shape drift -> second trace
    assert eng.trace_counts["decode[probe]"] == 2
    with pytest.raises(InvariantViolation, match="more than once"):
        eng.check()


def test_extra_transfer_is_detected():
    """An engine that adds a second host crossing to the decode hot path
    must fail the step-level transfer check."""

    class TwoFetchEngine(InstrumentedEngine):
        def _compiled_decode(self, sample):
            fn = super()._compiled_decode(sample)

            def wrapped(*a, **kw):
                ids, cache = fn(*a, **kw)
                self._fetch(ids)             # the regression under test
                return ids, cache

            return wrapped

    arch, params, cfg = _engine(batch_slots=1, max_ctx=32)
    eng = TwoFetchEngine(arch, params, cfg)
    eng.add_request([3, 1, 4])
    with pytest.raises(InvariantViolation, match="transfers"):
        eng.step()


def test_scheduler_invariants_clean():
    """The scheduler-layer drive (interleaved budgeted prefill over
    Poisson traffic) upholds the same compile/transfer budget: one decode
    executable, each bucket executable traced once, one fetch per
    admission + per decode step."""
    from repro.analysis.invariants import run_scheduler_invariants

    out = run_scheduler_invariants(configs=("qwen2-1.5b",))
    assert out["violations"] == 0 and out["failed"] == []
    rep = out["configs"]["qwen2-1.5b"]
    assert rep["completed"] == 5
    # budget 10 slices long prompts into a bucket-16 chunk + bucket-8
    # remainder: exactly two prefill executables, one trace each
    assert rep["prefill_executables"] == 2
    assert rep["compiles"] == 3                    # 2 prefill + 1 decode
    assert rep["fetches"] == rep["steps"] + 5      # no hidden transfers


def test_scheduler_extra_transfer_is_detected():
    """The injected second host crossing must still be caught when the
    decode step is issued by the continuous-batching scheduler rather
    than a hand-placed ``Engine.step`` call."""
    from repro.serving.scheduler import Scheduler, SchedulerConfig, StepClock

    class TwoFetchEngine(InstrumentedEngine):
        def _compiled_decode(self, sample):
            fn = super()._compiled_decode(sample)

            def wrapped(*a, **kw):
                ids, cache = fn(*a, **kw)
                self._fetch(ids)             # the regression under test
                return ids, cache

            return wrapped

    arch, params, cfg = _engine(batch_slots=1, max_ctx=32)
    clock = StepClock()
    sched = Scheduler(TwoFetchEngine(arch, params, cfg),
                      SchedulerConfig(prefill_token_budget=None),
                      clock=clock.now)
    sched.submit([3, 1, 4], max_new_tokens=4, arrival=0.0)
    with pytest.raises(InvariantViolation, match="transfers"):
        for _ in range(8):
            sched.step()
            clock.tick()
