"""Per-site (format × n_r × granularity) Pareto DSE regression net.

1. Dominance correctness on a hand-built 3-point front.
2. Budget-infeasible sites fall back to "off" with a UserWarning.
3. The emitted ``site_overrides`` round-trip through
   ``CIMConfig.for_site`` bit-identically (the chosen candidate IS the
   design the config resolves — pricing and policy can't disagree).
4. ``explore_sites`` (granularity-only at base formats) is reproduced by
   ``explore_pareto`` as the degenerate sweep.
5. The memoized solver (``core.adc.solve_required_enob``) matches the
   direct Monte-Carlo solve and is served from cache on re-query.
"""
import warnings

import jax
import pytest

from repro.configs import get_config
from repro.core import costs
from repro.core.adc import required_enob, solve_required_enob, \
    narrowest_uniform
from repro.core.cim_config import SiteDesign
from repro.core.dse import (GAIN_RANGE_LIMIT_BITS, SiteBudget,
                            explore_pareto, explore_sites, pareto_front,
                            spec_of_format)
from repro.core.formats import FP6_E3M2, FPFormat, IntFormat, parse_format

# small grids keep the test sweep to a handful of Monte-Carlo solves; the
# FULL ladder runs (and is gated) in the CI bench-smoke lane
_FMTS = (FP6_E3M2, FPFormat(2, 5), IntFormat(8))
_NRS = (16, 32)
_NC = 1 << 7


def _tiny(mode="grmac"):
    arch = get_config("paper-cim-120m").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=256, vocab_size=512)
    return arch.replace(cim=arch.cim.with_mode(mode))


# ----------------------------------------------------------- dominance
class _P:
    def __init__(self, fj, db):
        self.fj_per_op = fj
        self.sqnr_db = db


def test_pareto_front_three_point_dominance():
    a, b, c = _P(1.0, 10.0), _P(2.0, 20.0), _P(3.0, 15.0)
    # c is dominated by b (more energy, less accuracy); a and b trade off
    front = pareto_front([c, b, a])
    assert front == [a, b]
    # equal energy, lower accuracy is dominated; equal both keeps first
    d, e = _P(1.0, 5.0), _P(2.0, 20.0)
    assert pareto_front([a, d]) == [a]
    assert pareto_front([b, e]) == [b]
    # a point dominating everything is the whole front
    s = _P(0.5, 30.0)
    assert pareto_front([s, a, b, c]) == [s]


def test_deployment_front_monotone():
    arch = _tiny()
    ledger = costs.trace_decode(arch)
    res = explore_pareto(arch.cim, ledger, formats=_FMTS, n_r_set=_NRS,
                         budget=None, n_cols=_NC)
    front = res["front"]
    assert front, "deployment front must not be empty for a feasible sweep"
    pjs = [p["pj"] for p in front]
    dbs = [p["sqnr_db"] for p in front]
    # along the front: accuracy strictly up, energy strictly up
    assert dbs == sorted(dbs) and len(set(dbs)) == len(dbs)
    assert pjs == sorted(pjs) and len(set(pjs)) == len(pjs)
    # every front point's choices cover every swept site
    swept = [s for s, i in res["sites"].items() if "front" in i]
    for p in front:
        assert set(p["choices"]) == set(swept)


# -------------------------------------------------------------- budgets
def test_budget_infeasible_sites_fall_back_off_with_warning():
    arch = _tiny()
    ledger = costs.trace_decode(arch)
    with pytest.warns(UserWarning, match="accuracy budget"):
        res = explore_pareto(arch.cim, ledger, formats=_FMTS, n_r_set=_NRS,
                             budget=SiteBudget(min_sqnr_db=1000.0),
                             n_cols=_NC)
    assert res["site_overrides"], "swept sites must emit overrides"
    assert all(ov == "off" for ov in res["site_overrides"].values())
    assert res["pj"] == 0.0 and res["base_pj"] > 0.0
    assert res["front"] == []
    for site in res["site_overrides"]:
        assert not res["config"].for_site(site).enabled


def test_budget_filters_formats_and_enob_floor_converts():
    # 35 dB excludes FP6_E3M2 (22.8 dB) but admits FP8_E2M5 (40.9) & INT8
    assert spec_of_format(FP6_E3M2)[1] < 35.0 < spec_of_format(
        FPFormat(2, 5))[1]
    b = SiteBudget(min_sqnr_db=35.0)
    assert not b.admits(spec_of_format(FP6_E3M2)[1])
    assert b.admits(spec_of_format(FPFormat(2, 5))[1])
    # an ENOB floor converts through 6.02·N + 1.76 and the stricter wins
    both = SiteBudget(min_sqnr_db=20.0, min_enob=6.0)
    assert both.floor_db() == pytest.approx(6.02 * 6 + 1.76)
    assert SiteBudget(None, None).floor_db() is None
    arch = _tiny()
    ledger = costs.trace_decode(arch)
    res = explore_pareto(arch.cim, ledger, formats=_FMTS, n_r_set=_NRS,
                         budget=b, n_cols=_NC)
    for info in res["sites"].values():
        if "front" not in info:
            continue
        assert info["budget_sqnr_db"] == 35.0
        for c in info["front"]:
            assert c["sqnr_db"] >= 35.0


# ------------------------------------------------------------ roundtrip
def test_emitted_overrides_roundtrip_through_for_site():
    arch = _tiny()
    ledger = costs.trace_decode(arch)
    res = explore_pareto(arch.cim, ledger, formats=_FMTS, n_r_set=_NRS,
                         n_cols=_NC)
    cfg = res["config"]
    assert cfg == arch.cim.with_site_overrides(res["site_overrides"])
    for site, info in res["sites"].items():
        if "front" not in info or isinstance(info["chosen"], str):
            continue
        chosen = info["chosen"]
        eff = cfg.for_site(site)
        assert eff.granularity == chosen["granularity"]
        assert eff.fmt_x.name == chosen["fmt_x"]
        assert eff.n_r == chosen["n_r"]
        # and pricing the resolved config reproduces the chosen energy
        # bit-identically (same memoized solve)
        pt = costs.design_energy_fj(eff.granularity, eff.fmt_x, eff.fmt_w,
                                    eff.n_r, n_cols=_NC, seed=0)
        assert pt["fj_per_op"] == chosen["fj_per_op"]
        assert pt["enob"] == chosen["enob"]
        # the SiteDesign serializes and parses back to the same override
        ov = res["site_overrides"][site]
        assert SiteDesign.from_dict(ov.as_dict()) == ov


def test_parse_format_roundtrip():
    for fmt in (FP6_E3M2, FPFormat(2, 5), IntFormat(8), IntFormat(4)):
        assert parse_format(fmt.name) == fmt
    with pytest.raises(ValueError):
        parse_format("FP8_E9M9")   # name does not round-trip
    with pytest.raises(ValueError):
        parse_format("bogus")


# ------------------------------------------------- degenerate sweep
def test_degenerate_sweep_reproduces_explore_sites():
    arch = _tiny()
    ledger = costs.trace_decode(arch)
    base = arch.cim
    with warnings.catch_warnings():
        warnings.simplefilter("error")     # degenerate mode must not warn
        deg = explore_pareto(base, ledger, formats=(base.fmt_x,),
                             n_r_set=(base.n_r,), budget=None, n_cols=_NC)
    es = explore_sites(base, ledger, n_cols=_NC)
    assert deg["pj"] == es["pj"]
    assert deg["base_pj"] == es["base_pj"]
    for site, s in es["sites"].items():
        d = deg["sites"][site]
        if "granularity" not in s:          # digital site in both
            assert d.get("mode") == "off"
            continue
        chosen = d["chosen"]
        got_gran = chosen if isinstance(chosen, str) \
            else chosen["granularity"]
        assert got_gran == s["granularity"], site
        if not isinstance(chosen, str):
            assert chosen["fj_per_op"] == s["fj_per_op"]
            assert chosen["fmt_x"] == base.fmt_x.name
            assert chosen["n_r"] == base.n_r


# ----------------------------------------------- applying INT overrides
def test_int_override_runs_fakequant_and_fails_loudly_grmac():
    """An IntFormat per-site choice from the sweep is executable under
    fakequant (QAT of the gr_int deployment); grmac has no gr_int kernel
    backend and must say so instead of crashing mid-decompose."""
    import numpy as np
    from repro.models import forward, init_params
    arch = _tiny("fakequant")
    ov = SiteDesign(fmt_x=IntFormat(8), granularity="row", n_r=32)
    cfg = arch.cim.override_site("mlp", ov)
    assert cfg.for_site("mlp").fmt_x == IntFormat(8)
    mixed = arch.replace(cim=cfg)
    params = init_params(jax.random.PRNGKey(0), arch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                              arch.vocab_size)
    a, _, _ = forward(params, toks, arch)
    b, _, _ = forward(params, toks, mixed)
    assert np.all(np.isfinite(np.asarray(b)))
    assert np.any(np.asarray(a) != np.asarray(b))  # the site really moved
    grmac = _tiny("grmac")
    bad = grmac.replace(cim=grmac.cim.override_site("mlp", ov))
    with pytest.raises(NotImplementedError, match="gr_int"):
        forward(params, toks, bad)


def test_override_site_rejects_unknown_site():
    arch = _tiny()
    with pytest.raises(ValueError, match="unknown site"):
        arch.cim.override_site("attn_kqv", "off")   # typo'd label
    # canonical sites and legacy family names both pass
    arch.cim.override_site("attn_qkv", "off")
    arch.cim.override_site("ffn", "off")


# ------------------------------------------------------------ solver memo
def test_solver_memo_matches_direct_solve_and_caches():
    fmt = FP6_E3M2
    direct = required_enob(jax.random.PRNGKey(0), "gr_row",
                           narrowest_uniform(fmt), fmt, n_r=16,
                           n_cols=_NC)
    memo = solve_required_enob("gr_row", fmt, 16, n_cols=_NC, seed=0)
    assert memo.enob == direct.enob
    assert memo.mean_scale_sq == direct.mean_scale_sq
    # cache hit: the very same result object comes back
    assert solve_required_enob("gr_row", fmt, 16, n_cols=_NC, seed=0) \
        is memo


def test_gain_range_prunes_wide_exponents_at_every_n_r():
    """The coupling-ladder limit is n_r-invariant: FP8_E4M3 (e_max=15) can
    only enter the space through conv, at any depth."""
    from repro.core.energy import CimDesign
    from repro.core.formats import FP8_E4M3, FP4_E2M1
    for n_r in (16, 32, 64, 128):
        d = CimDesign("gr_row", FP8_E4M3, FP4_E2M1, 0.0, n_r)
        assert d.gain_range_bits > GAIN_RANGE_LIMIT_BITS
        c = CimDesign("conv", FP8_E4M3, FP4_E2M1, 0.0, n_r)
        assert c.gain_range_bits == 0
    arch = _tiny()
    ledger = costs.trace_decode(arch)
    res = explore_pareto(arch.cim, ledger, formats=(FP8_E4M3,),
                         n_r_set=(16, 32), budget=None, n_cols=_NC)
    for info in res["sites"].values():
        if "front" not in info:
            continue
        for c in info["front"]:
            assert c["granularity"] == "conv"
