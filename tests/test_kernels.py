"""GR-MAC backend cross-validation: fast XLA path and fused tiled path vs
the jnp oracle (exact), Pallas-interpret vs oracle (slow debug cross-check),
plan-based dispatch (heuristic + autotune cache), and the model-facing
cim_matmul op."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cim_config import CIMConfig
from repro.core.formats import FP4_E2M1, FP6_E3M2, FPFormat, quantize
from repro.kernels.dispatch import (
    BACKENDS,
    Plan,
    clear_plan_cache,
    grmac_matmul,
    plan_for,
    resolve_backend,
)
from repro.kernels.grmac_matmul import grmac_matmul_pallas
from repro.kernels.ops import cim_matmul
from repro.kernels.ref import grmac_matmul_ref
from repro.kernels.tiled import default_tile_m, grmac_matmul_tiled
from repro.kernels.xla import bf16_products_exact, grmac_matmul_xla


def _data(key, m, k, n):
    kx, kw = jax.random.split(key)
    x = jax.random.uniform(kx, (m, k), minval=-1.0, maxval=1.0)
    w = quantize(jax.random.uniform(kw, (k, n), minval=-1.0, maxval=1.0), FP4_E2M1)
    return x, w


# ------------------------------------------------------------- fast XLA path
@pytest.mark.parametrize("granularity", ["conv", "row", "unit"])
@pytest.mark.parametrize(
    "m,k,n", [(128, 128, 128), (256, 384, 128), (128, 256, 256)]
)
def test_xla_backend_matches_ref(granularity, m, k, n):
    x, w = _data(jax.random.PRNGKey(0), m, k, n)
    kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
              granularity=granularity)
    ref = grmac_matmul_ref(x, w, **kw)
    out = grmac_matmul_xla(x, w, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("granularity", ["conv", "row", "unit"])
def test_xla_backend_unpadded_shapes(granularity):
    # no 128-alignment requirement: dispatch pads K to n_r only
    x, w = _data(jax.random.PRNGKey(7), 7, 100, 13)
    kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
              granularity=granularity)
    ref = grmac_matmul(x, w, backend="ref", **kw)
    out = grmac_matmul(x, w, backend="xla", **kw)
    assert out.shape == (7, 13)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_xla_backend_vmap_grad_safe():
    x, w = _data(jax.random.PRNGKey(8), 32, 128, 16)
    kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
              granularity="row")
    vm = jax.vmap(lambda a: grmac_matmul_xla(a, w, **kw))(
        jnp.stack([x, x * 0.5, -x]))
    assert vm.shape == (3, 32, 16)
    g = jax.grad(lambda a: jnp.sum(grmac_matmul_xla(a, w, **kw) ** 2))(x)
    assert g.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(g)))


# ------------------------------------------------------------- tiled path
@pytest.mark.parametrize("granularity", ["conv", "row", "unit"])
@pytest.mark.parametrize(
    "m,k,n,tile_m,tile_n",
    [
        (128, 128, 128, 32, 0),      # tiles divide M, no N tiling
        (100, 128, 96, 32, 0),       # tile_m does not divide M
        (64, 128, 80, 16, 32),       # N tiling, divides
        (64, 128, 80, 16, 24),       # N tiling, does not divide
        (24, 128, 48, 256, 0),       # single tile larger than M
    ],
)
def test_tiled_backend_matches_ref_exactly(granularity, m, k, n,
                                           tile_m, tile_n):
    """The fused tiled backend is bit-identical to the oracle at 0 ulp for
    every granularity and for tile sizes that do and don't divide M/N."""
    x, w = _data(jax.random.PRNGKey(21), m, k, n)
    kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
              granularity=granularity)
    ref = grmac_matmul_ref(x, w, **kw)
    out = grmac_matmul_tiled(x, w, tile_m=tile_m, tile_n=tile_n, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("n_r", [8, 32, 128])
def test_tiled_backend_n_r_edges(n_r):
    """n_r from one block per row (n_r == K) down to many tiny columns."""
    x, w = _data(jax.random.PRNGKey(22), 48, 128, 40)
    for gran in ["conv", "row", "unit"]:
        kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=n_r, enob=8.0,
                  granularity=gran)
        ref = grmac_matmul_ref(x, w, **kw)
        out = grmac_matmul_tiled(x, w, tile_m=16, **kw)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_tiled_backend_vmap_grad_safe():
    x, w = _data(jax.random.PRNGKey(23), 32, 128, 16)
    kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
              granularity="row")
    vm = jax.vmap(lambda a: grmac_matmul_tiled(a, w, tile_m=8, **kw))(
        jnp.stack([x, x * 0.5, -x]))
    assert vm.shape == (3, 32, 16)
    g = jax.grad(
        lambda a: jnp.sum(grmac_matmul_tiled(a, w, tile_m=8, **kw) ** 2))(x)
    assert g.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(g)))


def test_tiled_through_dispatch_unpadded():
    """dispatch pads K to n_r for the tiled backend exactly like xla/ref."""
    x, w = _data(jax.random.PRNGKey(24), 70, 100, 13)
    kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
              granularity="row")
    ref = grmac_matmul(x, w, backend="ref", **kw)
    out = grmac_matmul(x, w, backend="tiled", **kw)
    tiny = grmac_matmul(x, w, backend="tiled", tile_m=16, tile_n=8, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(tiny), np.asarray(ref))


# ----------------------------------------------------- bf16 values variant
@pytest.mark.parametrize("granularity", ["conv", "row", "unit"])
def test_xla_bf16_values_matches_ref_exactly(granularity):
    """FP6_E3M2 x FP4_E2M1 products carry 5 significand bits, so the bf16
    values-einsum variant must agree with the oracle at 0 ulp (CPU
    contract; see the accumulation-order caveat in kernels/xla.py)."""
    assert bf16_products_exact(FP6_E3M2, FP4_E2M1)
    x, w = _data(jax.random.PRNGKey(11), 64, 256, 48)
    kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
              granularity=granularity)
    ref = grmac_matmul_ref(x, w, **kw)
    out = grmac_matmul_xla(x, w, bf16_values=True, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_xla_bf16_values_env_flag(monkeypatch):
    """REPRO_GRMAC_BF16_VALUES=1 routes dispatch through the bf16 variant
    and keeps the 0-ulp cross-backend contract on every granularity."""
    monkeypatch.setenv("REPRO_GRMAC_BF16_VALUES", "1")
    x, w = _data(jax.random.PRNGKey(12), 7, 100, 13)  # unpadded shapes too
    for gran in ["conv", "row", "unit"]:
        kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
                  granularity=gran)
        ref = grmac_matmul(x, w, backend="ref", **kw)
        out = grmac_matmul(x, w, backend="xla", **kw)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_xla_bf16_values_falls_back_for_wide_formats():
    """Formats whose products exceed bf16's 8 significand bits must ignore
    the flag (silent f32 fallback keeps numerics unconditionally safe)."""
    wide = FPFormat(3, 6)          # 7 + 2 significand bits > 8
    assert not bf16_products_exact(wide, FP4_E2M1)
    x, w = _data(jax.random.PRNGKey(13), 32, 128, 16)
    kw = dict(fmt_x=wide, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
              granularity="row")
    ref = grmac_matmul_xla(x, w, bf16_values=False, **kw)
    out = grmac_matmul_xla(x, w, bf16_values=True, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------- dispatch
_FMT_KW = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1)


def test_dispatch_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_GRMAC_BACKEND", raising=False)
    # "auto" stays symbolic at the name level; plan_for decides per shape
    assert resolve_backend(None) == "auto"
    assert resolve_backend("ref") == "ref"
    monkeypatch.setenv("REPRO_GRMAC_BACKEND", "ref")
    assert resolve_backend(None) == "ref"
    assert resolve_backend("auto") == "ref"
    assert resolve_backend("xla") == "xla"  # explicit beats env
    with pytest.raises(ValueError):
        resolve_backend("cuda")
    assert set(BACKENDS) == {"auto", "xla", "tiled", "pallas",
                             "pallas_interpret", "ref"}


def test_plan_heuristic_small_vs_large_m(monkeypatch):
    """The static heuristic routes the deployment regimes: edge_decode
    (16x768x3072) to the batched-einsum xla path, train_large_m
    (2048x768x3072) to the fused tiled path (off-TPU)."""
    if jax.default_backend() == "tpu":
        pytest.skip("heuristic plans pallas on TPU")
    monkeypatch.delenv("REPRO_GRMAC_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_GRMAC_AUTOTUNE", raising=False)
    monkeypatch.setenv("REPRO_GRMAC_PLAN_CACHE", "/nonexistent/plans.json")
    clear_plan_cache()
    edge = plan_for(16, 768, 3072, granularity="row", **_FMT_KW)
    train = plan_for(2048, 768, 3072, granularity="row", **_FMT_KW)
    assert edge.backend == "xla"
    assert train.backend == "tiled"
    assert train.tile_m == default_tile_m(768, 3072, 32)
    # explicit names always short-circuit the planner
    assert plan_for(2048, 768, 3072, granularity="row", backend="ref",
                    **_FMT_KW) == Plan("ref", source="fixed")
    clear_plan_cache()


def test_autotune_cache_round_trip(tmp_path, monkeypatch):
    """REPRO_GRMAC_AUTOTUNE=1 probes an unknown shape once, persists the
    winning plan to the JSON cache, and a fresh lookup (new in-memory
    state, autotune off) serves the persisted plan instead of re-probing
    or falling back to the heuristic."""
    cache = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_GRMAC_PLAN_CACHE", str(cache))
    monkeypatch.setenv("REPRO_GRMAC_AUTOTUNE", "1")
    monkeypatch.delenv("REPRO_GRMAC_BACKEND", raising=False)
    clear_plan_cache()
    probed = plan_for(96, 96, 64, granularity="row", **_FMT_KW)
    assert probed.source == "autotune"
    assert cache.exists()

    clear_plan_cache()                      # drop memory, keep the file
    monkeypatch.setenv("REPRO_GRMAC_AUTOTUNE", "0")
    reloaded = plan_for(96, 96, 64, granularity="row", **_FMT_KW)
    assert reloaded.source == "cache"
    assert (reloaded.backend, reloaded.tile_m, reloaded.tile_n) == \
        (probed.backend, probed.tile_m, probed.tile_n)
    # a different shape/granularity is a different key -> heuristic again
    other = plan_for(96, 96, 64, granularity="unit", **_FMT_KW)
    assert other.source == "heuristic"
    clear_plan_cache()


def test_plan_cache_version_mismatch_ignored(tmp_path, monkeypatch):
    """A plan cache written under a different schema version — including a
    pre-versioned flat dict — is ignored with a warning, not served: its
    plans may have been measured under different rules. The next persisted
    plan rewrites the file under the current version."""
    import json
    import warnings

    from repro.kernels.dispatch import PLAN_CACHE_VERSION, _plan_key

    if jax.default_backend() == "tpu":
        pytest.skip("heuristic plans pallas on TPU; test pins xla/tiled")
    cache = tmp_path / "plans.json"
    key = _plan_key(16, 96, 64, "row", _FMT_KW["fmt_x"], _FMT_KW["fmt_w"], 32)
    stale = {"backend": "ref", "tile_m": 0, "tile_n": 0, "warm_us": 1.0}
    monkeypatch.setenv("REPRO_GRMAC_PLAN_CACHE", str(cache))
    monkeypatch.delenv("REPRO_GRMAC_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_GRMAC_AUTOTUNE", raising=False)

    for payload in (
        {key: stale},                                     # pre-versioned
        {"version": PLAN_CACHE_VERSION + 1, "plans": {key: stale}},
    ):
        cache.write_text(json.dumps(payload))
        clear_plan_cache()
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            plan = plan_for(16, 96, 64, granularity="row", **_FMT_KW)
        assert plan.source == "heuristic"      # stale "ref" plan NOT served
        assert plan.backend == "xla"
        assert any("plan cache" in str(w.message) for w in caught)

    # a current-version cache IS served
    cache.write_text(json.dumps(
        {"version": PLAN_CACHE_VERSION, "plans": {key: stale}}))
    clear_plan_cache()
    assert plan_for(16, 96, 64, granularity="row", **_FMT_KW).source == "cache"
    clear_plan_cache()


def test_auto_dispatch_matches_ref_under_jit(monkeypatch):
    """backend="auto" plans inside jit traces (no probing) and the planned
    backend keeps the 0-ulp contract."""
    monkeypatch.delenv("REPRO_GRMAC_BACKEND", raising=False)
    monkeypatch.setenv("REPRO_GRMAC_AUTOTUNE", "1")  # must not probe in-trace
    monkeypatch.setenv("REPRO_GRMAC_PLAN_CACHE", "/nonexistent/plans.json")
    clear_plan_cache()
    x, w = _data(jax.random.PRNGKey(25), 96, 96, 48)
    kw = dict(n_r=32, enob=8.0, granularity="row", **_FMT_KW)
    ref = grmac_matmul(x, w, backend="ref", **kw)
    out = jax.jit(lambda a, b: grmac_matmul(a, b, backend="auto", **kw))(x, w)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    clear_plan_cache()


def test_cim_matmul_backend_kwarg():
    x, w = _data(jax.random.PRNGKey(9), 16, 96, 24)
    cfg = CIMConfig(mode="grmac", granularity="row", n_r=32)
    a = cim_matmul(x, w, cfg, backend="xla")
    b = cim_matmul(x, w, cfg, backend="ref")
    c = cim_matmul(x, w, cfg.with_backend("xla"))
    d = cim_matmul(x, w, cfg, use_kernel=False)  # legacy knob -> xla
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(d))


@pytest.mark.parametrize("backend", BACKENDS)
def test_grmac_with_intformat_raises_not_implemented(backend):
    """grmac execution has no INT signal chain (the gr_int ladder is priced
    analytically by core.dse only): every backend must refuse an IntFormat
    input with the same actionable error through the model-facing op, not
    trace into a wrong-numerics kernel."""
    from repro.core.formats import IntFormat
    x, w = _data(jax.random.PRNGKey(11), 16, 64, 8)
    cfg = CIMConfig(mode="grmac", granularity="row", n_r=32,
                    fmt_x=IntFormat(8))
    with pytest.raises(NotImplementedError, match="IntFormat"):
        cim_matmul(x, w, cfg, backend=backend)
    # fakequant, by contrast, supports the INT ladder: same config must run
    out = cim_matmul(x, w, CIMConfig(mode="fakequant", granularity="row",
                                     n_r=32, fmt_x=IntFormat(8)))
    assert out.shape == (16, 8)
    assert bool(jnp.all(jnp.isfinite(out)))


# ----------------------------------------- Pallas interpret-mode cross-check
@pytest.mark.slow
@pytest.mark.parametrize("granularity", ["conv", "row", "unit"])
@pytest.mark.parametrize(
    "m,k,n", [(128, 128, 128), (256, 384, 128), (128, 256, 256)]
)
def test_kernel_matches_ref(granularity, m, k, n):
    x, w = _data(jax.random.PRNGKey(0), m, k, n)
    fmt_w = FP4_E2M1
    fmt_x = FP6_E3M2
    kw = dict(fmt_x=fmt_x, fmt_w=fmt_w, n_r=32, enob=8.0, granularity=granularity)
    ref = grmac_matmul_ref(x, w, **kw)
    out = grmac_matmul_pallas(x, w, block_m=128, block_n=128, block_k=128,
                              interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("fmt_x", [FP4_E2M1, FP6_E3M2, FPFormat(2, 3)])
def test_kernel_shape_dtype_sweep(dtype, fmt_x):
    x, w = _data(jax.random.PRNGKey(1), 128, 128, 128)
    x = x.astype(dtype)
    kw = dict(fmt_x=fmt_x, fmt_w=FP4_E2M1, n_r=32, enob=8.0, granularity="row")
    ref = grmac_matmul_ref(x.astype(jnp.float32), w, **kw)
    out = grmac_matmul_pallas(x.astype(jnp.float32), w, interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_kernel_multi_kblock_accumulation():
    # K spans several kernel grid steps AND several n_r sub-blocks per step.
    x, w = _data(jax.random.PRNGKey(2), 128, 512, 128)
    kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=64, enob=9.0, granularity="unit")
    ref = grmac_matmul_ref(x, w, **kw)
    out = grmac_matmul_pallas(x, w, block_k=128, interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_dispatch_pallas_interpret_matches_xla():
    """The debug backend and the fast backend agree through dispatch,
    including the shared zero-padding contract."""
    x, w = _data(jax.random.PRNGKey(3), 64, 160, 40)
    kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
              granularity="row")
    slow = grmac_matmul(x, w, backend="pallas_interpret", **kw)
    fast = grmac_matmul(x, w, backend="xla", **kw)
    np.testing.assert_allclose(np.asarray(slow), np.asarray(fast),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- cim_matmul op
def test_grmac_accuracy_vs_fakequant():
    # GR-MAC adds only ADC noise on top of format quantization: the distance
    # to the fakequant (exact-accumulation) output must be small at ENOB=8.
    x, w = _data(jax.random.PRNGKey(3), 64, 256, 64)
    cfg_fq = CIMConfig(mode="fakequant", granularity="row", n_r=32)
    cfg_gr = CIMConfig(mode="grmac", granularity="row", n_r=32)
    fq = cim_matmul(x, w, cfg_fq, use_kernel=False)
    gr = cim_matmul(x, w, cfg_gr, use_kernel=False)
    rel = float(jnp.linalg.norm(gr - fq) / jnp.linalg.norm(fq))
    assert rel < 0.05, rel


def test_cim_matmul_modes_and_grad():
    x, w = _data(jax.random.PRNGKey(4), 32, 96, 48)
    for mode in ["off", "fakequant", "grmac"]:
        cfg = CIMConfig(mode=mode)
        out = cim_matmul(x, w, cfg, use_kernel=False)
        assert out.shape == (32, 48)
        assert bool(jnp.all(jnp.isfinite(out)))

    cfg = CIMConfig(mode="grmac")
    f = lambda xx, ww: jnp.sum(cim_matmul(xx, ww, cfg, use_kernel=False) ** 2)
    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    assert gx.shape == x.shape and gw.shape == w.shape
    assert bool(jnp.all(jnp.isfinite(gx))) and bool(jnp.all(jnp.isfinite(gw)))


def test_batched_leading_dims():
    x = jax.random.uniform(jax.random.PRNGKey(5), (4, 8, 96), minval=-1, maxval=1)
    w = jax.random.uniform(jax.random.PRNGKey(6), (96, 32), minval=-1, maxval=1)
    cfg = CIMConfig(mode="grmac")
    out = cim_matmul(x, w, cfg, use_kernel=False)
    assert out.shape == (4, 8, 32)
