"""Unit + property tests for the core CIM library (formats, MAC, ADC, energy)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import adc as A
from repro.core import distributions as D
from repro.core import energy as E
from repro.core import formats as F
from repro.core import mac as M

FMT_STRAT = st.tuples(st.integers(1, 4), st.integers(1, 5)).map(
    lambda t: F.FPFormat(*t)
)


# ---------------------------------------------------------------- formats
@settings(max_examples=30, deadline=None)
@given(fmt=FMT_STRAT, seed=st.integers(0, 2**31 - 1))
def test_quantize_idempotent_and_bounded(fmt, seed):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (512,), minval=-1, maxval=1)
    xq = F.quantize(x, fmt)
    np.testing.assert_allclose(F.quantize(xq, fmt), xq, rtol=0, atol=0)
    assert float(jnp.max(jnp.abs(xq))) <= fmt.max_value
    # quantization error bounded by half LSB at each value's exponent
    # (excluding saturated samples, which clamp to max_value by design)
    _, _, e = F.decompose(xq, fmt)
    lsb = F.pow2i(e - fmt.e_max - fmt.n_man - 1)
    sat = jnp.abs(x) >= fmt.max_value
    assert bool(jnp.all(sat | (jnp.abs(x - xq) <= 0.5 * lsb + 1e-7)))


@settings(max_examples=30, deadline=None)
@given(fmt=FMT_STRAT, seed=st.integers(0, 2**31 - 1))
def test_decompose_compose_roundtrip(fmt, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (512,)) * 0.3
    xq = F.quantize(jnp.clip(x, -1, 1), fmt)
    s, m, e = F.decompose(xq, fmt)
    rec = F.compose(s, m, e, fmt)
    np.testing.assert_allclose(rec, xq, rtol=0, atol=1e-9)
    assert bool(jnp.all((e >= 1) & (e <= fmt.e_max)))
    assert bool(jnp.all((m >= 0) & (m < 1)))


def test_fp_sqnr_formula_distribution_invariant():
    """C1: measured SQNR tracks 6.02 N_M + 10.79 dB, independent of data."""
    key = jax.random.PRNGKey(0)
    for fmt in [F.FPFormat(2, 2), F.FPFormat(3, 3), F.FPFormat(3, 4)]:
        for dist in [D.uniform(), D.gaussian_clipped(4.0)]:
            x = dist(key, (1 << 18,))
            got = float(F.measured_sqnr_db(x, F.quantize(x, fmt)))
            # in-range data => within ~3.5 dB of the formula (the paper states ≈)
            assert abs(got - F.sqnr_db(fmt)) < 3.5, (fmt.name, dist.name, got)


def test_max_entropy_on_grid():
    fmt = F.FP6_E3M2
    x = F.max_entropy_sample(jax.random.PRNGKey(1), (1 << 16,), fmt)
    np.testing.assert_array_equal(np.asarray(F.quantize(x, fmt)), np.asarray(x))


def test_int_quantize_grid():
    fmt = F.IntFormat(4)
    x = jnp.linspace(-1, 1, 1001)
    xq = F.int_quantize(x, fmt)
    codes = np.asarray(xq) * fmt.levels
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-5)


# ---------------------------------------------------------------- MAC chains
def _col_data(key, n_r=32, cols=2048, fmt=F.FP6_E3M2):
    kx, kw = jax.random.split(key)
    dist = D.gaussian_clipped(4.0)
    xq = F.quantize(dist(kx, (cols, n_r)), fmt)
    wq = F.quantize(dist(kw, (cols, n_r)), fmt)
    return xq, wq


@pytest.mark.parametrize("gran", ["row", "unit"])
def test_grmac_reconstructs_exact_dot(gran):
    """With an ideal ADC the GR-MAC reproduces Σ x·w exactly (§III-B2)."""
    fmt = F.FP6_E3M2
    xq, wq = _col_data(jax.random.PRNGKey(0), fmt=fmt)
    fn = M.gr_mac_row if gran == "row" else M.gr_mac_unit
    args = (xq, wq, fmt) if gran == "row" else (xq, wq, fmt, fmt)
    out = fn(*args, 30.0)
    ref = jnp.sum(xq * wq, axis=-1)
    np.testing.assert_allclose(np.asarray(out.z), np.asarray(ref), rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(out.v))) <= 1.0 + 1e-6  # no clipping ever


def test_voltage_never_clips_property():
    """GR-MAC compute-line voltage is a weighted mean of |.|<=1 values."""
    for seed in range(5):
        xq, wq = _col_data(jax.random.PRNGKey(seed))
        for out in [
            M.gr_mac_row(xq, wq, F.FP6_E3M2, 8.0),
            M.gr_mac_unit(xq, wq, F.FP6_E3M2, F.FP6_E3M2, 8.0),
            M.int_mac(xq, wq, 8.0),
        ]:
            assert float(jnp.max(jnp.abs(out.v))) <= 1.0 + 1e-6


def test_n_eff_bounds():
    xq, wq = _col_data(jax.random.PRNGKey(2))
    out = M.gr_mac_unit(xq, wq, F.FP6_E3M2, F.FP6_E3M2, 8.0)
    n_r = xq.shape[-1]
    assert bool(jnp.all(out.n_eff <= n_r + 1e-4))
    assert bool(jnp.all(out.n_eff >= 1.0 - 1e-4))
    # equal exponents -> N_eff == N_R exactly
    g = jnp.ones((7, n_r))
    np.testing.assert_allclose(np.asarray(M.n_eff(g)), n_r, rtol=1e-6)


def test_adc_quantizer():
    v = jnp.linspace(-1, 1, 999)
    vq = M.adc_quantize(v, 6.0)
    delta = 2.0 / 2**6
    np.testing.assert_allclose(np.asarray(vq / delta), np.round(np.asarray(vq / delta)), atol=1e-5)
    assert float(jnp.max(jnp.abs(v - vq))) <= delta / 2 + 1e-6


# ---------------------------------------------------------------- ADC solver
def test_enob_monotone_in_margin():
    key = jax.random.PRNGKey(0)
    r6 = A.required_enob(key, "conv", D.uniform(), F.FP6_E3M2, margin_db=6.0)
    r12 = A.required_enob(key, "conv", D.uniform(), F.FP6_E3M2, margin_db=12.0)
    assert r12.enob > r6.enob


def test_paper_claim_C2_upper_bound_1p5_bits():
    """GR unit-norm upper bound (uniform) >= 1.5 b below conventional lower bound."""
    key = jax.random.PRNGKey(0)
    deltas = []
    for ne in (2, 3, 4):
        fmt = F.FPFormat(ne, 2)
        rc = A.required_enob(key, "conv", D.uniform(), fmt)
        ru = A.required_enob(key, "gr_unit", D.uniform(), fmt)
        deltas.append(rc.enob - ru.enob)
    assert min(deltas) >= 1.3, deltas  # paper: 1.5 b (MC tolerance)


def test_paper_claim_C3_outliers_6_bits():
    key = jax.random.PRNGKey(0)
    fmt = F.FPFormat(3, 2)
    rc = A.required_enob(key, "conv", D.gaussian_outliers(), fmt)
    ru = A.required_enob(key, "gr_unit", D.gaussian_outliers(), fmt)
    assert rc.enob - ru.enob > 6.0, (rc.enob, ru.enob)


def test_paper_claim_C8_below_thermal_crossover():
    key = jax.random.PRNGKey(0)
    ncross = E.TechParams().n_cross()
    assert 9.5 < ncross < 10.5  # ~10 b (paper §III-B)
    for ne in (2, 3, 4):
        ru = A.required_enob(key, "gr_unit", D.uniform(), F.FPFormat(ne, 2))
        assert ru.enob < ncross


# ---------------------------------------------------------------- energy
def test_adc_energy_regimes():
    p = E.TechParams()
    # technology-limited regime: roughly linear
    lo = E.adc_energy_fj(4, p) / 4
    hi = E.adc_energy_fj(8, p) / 8
    assert hi / lo < 2.0
    # thermal regime: quadrupling per bit
    r = E.adc_energy_fj(14, p) / E.adc_energy_fj(13, p)
    assert 3.0 < r < 4.5


def test_adder_tree_count():
    # 2 inputs of width w -> w FAs
    assert E.adder_tree_fa_count(2, 4) == 4
    assert E.adder_tree_fa_count(4, 1) == 2 * 1 + 1 * 2
    assert E.adder_tree_fa_count(32, 3) > 0


def test_energy_breakdown_positive_and_total():
    d = E.CimDesign("gr_row", F.FP6_E3M2, F.FP4_E2M1, enob=9.0)
    b = E.energy_per_op_fj(d)
    assert b.adc > 0 and b.dac > 0 and b.cells > 0 and b.logic > 0
    assert abs(b.total - (b.adc + b.dac + b.cells + b.logic)) < 1e-12


def test_gain_range_limit():
    d = E.CimDesign("gr_unit", F.FPFormat(4, 2), F.FPFormat(4, 1), enob=8.0)
    assert d.gain_range_bits == (15 - 1) + (15 - 1)  # way past the 6 b limit


def test_paper_claim_C6_fp6_native():
    """FP6_E3M2: GR processes natively ~29 fJ/Op; conventional out of range."""
    from repro.core import dse as S

    pt = S.evaluate_point(jax.random.PRNGKey(2), F.FP6_E3M2, n_cols=1 << 12)
    assert pt.gr is not None and pt.gr.total < 40.0, pt.gr
    assert pt.conv.total > 100.0  # beyond the practical energy limit


def test_global_normalization_roundtrip_and_truncation():
    """FP->INT global normalization (§II-B2): lossless when the INT width
    covers mantissa+shift range; lossy (truncation) when narrower — the
    overhead the GR-MAC eliminates."""
    fmt = F.FP6_E3M2
    x = F.quantize(D.gaussian_clipped(4.0)(jax.random.PRNGKey(0), (512, 32)),
                   fmt)
    full_width = (fmt.n_man + 1) + (fmt.e_max - 1) + 1  # mantissa+shift+sign
    aligned, scale = M.global_normalize(x, fmt, full_width)
    np.testing.assert_allclose(np.asarray(aligned * scale), np.asarray(x),
                               atol=1e-6)
    # narrow INT: truncation error appears
    aligned8, scale8 = M.global_normalize(x, fmt, 6)
    err = float(jnp.mean(jnp.abs(aligned8 * scale8 - x)))
    assert err > 1e-5
