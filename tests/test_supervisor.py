"""Resilience loop: GoodPut ledger accounting, torn-checkpoint crash
drills, cross-tier restore fallback, fault-plan determinism, and the
supervised fault drill end-to-end (inject -> detect -> restore ->
elastic resume, with a bit-identical recomputed trajectory)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.training import checkpoint as ckpt
from repro.training import fault
from repro.training.supervisor import (
    DrillConfig,
    GoodPutLedger,
    SimFleet,
    Supervisor,
    price_drill,
)
from repro.training.trainer import TrainConfig, make_train_step
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.models import init_params


# ------------------------------------------------------------- ledger
def test_ledger_partitions_wall_clock():
    t = {"now": 0.0}
    led = GoodPutLedger(clock=lambda: t["now"]).start()
    t["now"] = 3.0
    led.to("productive")
    t["now"] = 10.0
    with led.in_bucket("checkpoint_stall"):
        t["now"] = 11.0
    t["now"] = 15.0
    wall = led.close()
    assert wall == 15.0
    assert led.buckets["overhead"] == 3.0      # start..to(productive)
    assert led.buckets["productive"] == 11.0   # 3..10 and 11..15
    assert led.buckets["checkpoint_stall"] == 1.0
    assert sum(led.buckets.values()) == wall
    assert led.report()["goodput_pct"] == pytest.approx(100 * 11 / 15)


def test_ledger_partition_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(
        st.tuples(st.sampled_from(GoodPutLedger.BUCKETS),
                  st.integers(min_value=0, max_value=1000)),
        max_size=40))
    def check(moves):
        t = {"now": 0.0}
        led = GoodPutLedger(clock=lambda: t["now"]).start()
        for bucket, dt in moves:
            led.to(bucket)
            t["now"] += dt
        wall = led.close()
        # integer-valued fake clock -> float sums are exact
        assert sum(led.buckets.values()) == wall

    check()


def test_ledger_rejects_misuse():
    led = GoodPutLedger()
    with pytest.raises(RuntimeError):
        led.to("productive")     # start() never called
    with pytest.raises(KeyError):
        led.start().to("nope")


# -------------------------------------------- torn-checkpoint crash drills
def _tree(scale=1.0):
    return {"a": jnp.arange(6.0).reshape(2, 3) * scale,
            "b": {"c": jnp.ones((4,), jnp.float32) * scale}}


def test_writer_crash_between_leaf_writes(tmp_path, monkeypatch):
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, 1, _tree(1.0))
    snap = ckpt.snapshot_tree(_tree(2.0))

    calls = {"n": 0}
    real_save = np.save

    def dying_save(path, arr, **kw):
        calls["n"] += 1
        if calls["n"] >= 2:
            raise OSError("writer killed between leaf writes")
        return real_save(path, arr, **kw)

    with monkeypatch.context() as m:
        m.setattr(np, "save", dying_save)
        with pytest.raises(OSError):
            ckpt.write_snapshot(d, 2, snap)

    # the torn step was never published: restore loads the prior one
    assert ckpt.latest_step(d) == 1
    got, step = ckpt.restore_checkpoint(d, _tree())
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(_tree(1.0)["a"]))


def test_writer_crash_between_meta_and_rename(tmp_path, monkeypatch):
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, 1, _tree(1.0))

    def dying_rename(src, dst):
        raise OSError("writer killed before the atomic publish")

    with monkeypatch.context() as m:
        m.setattr(ckpt.os, "rename", dying_rename)
        with pytest.raises(OSError):
            ckpt.write_snapshot(d, 2, ckpt.snapshot_tree(_tree(2.0)))

    # meta.json exists only inside the .tmp dir -> not a candidate
    assert any(".tmp" in n for n in os.listdir(d))
    assert ckpt.latest_step(d) == 1
    _, step = ckpt.restore_checkpoint(d, _tree())
    assert step == 1


def test_async_torn_local_falls_back_to_durable_tier(tmp_path, monkeypatch):
    w = ckpt.AsyncCheckpointer(
        str(tmp_path / "durable"), str(tmp_path / "local"),
        durable_every=1, local_every=1)
    w.save(1, _tree(1.0), ("durable",))
    w.drain()

    def dying_save(path, arr, **kw):
        raise OSError("local medium died mid-write")

    with monkeypatch.context() as m:
        m.setattr(np, "save", dying_save)
        w.save(2, _tree(2.0), ("local",))
        with pytest.warns(UserWarning, match="never published"):
            w.drain()

    # the torn local step 2 must not exist; restore falls back cross-tier
    state, step, tier = w.restore(_tree())
    assert (step, tier) == (1, "durable")
    np.testing.assert_array_equal(np.asarray(state["a"]),
                                  np.asarray(_tree(1.0)["a"]))
    w.close()


def test_restore_skips_corrupt_newest_with_warning(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, 2, _tree(1.0))
    ckpt.save_checkpoint(d, 5, _tree(2.0))
    # bit-rot the newest step's first leaf
    sd = os.path.join(d, "step_000000005")
    leaf = sorted(n for n in os.listdir(sd) if n.endswith(".npy"))[0]
    with open(os.path.join(sd, leaf), "r+b") as f:
        f.seek(90)
        f.write(b"\xde\xad\xbe\xef")

    with pytest.warns(UserWarning, match="step 5"):
        got, step = ckpt.restore_checkpoint(d, _tree())
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(_tree(1.0)["a"]))
    with pytest.warns(UserWarning, match="step 5"):
        assert ckpt.latest_step(d, verify=True) == 2
    # an explicit step request still fails loudly
    with pytest.raises(IOError):
        ckpt.restore_checkpoint(d, _tree(), step=5)


# ------------------------------------------------------- fleet + plans
def test_sim_fleet_detects_only_dead_hosts(tmp_path):
    board = fault.HeartbeatBoard(str(tmp_path / "hb"))
    fleet = SimFleet(board, n_hosts=4, chips_per_host=2, timeout_s=3.0)
    fleet.beat_all(0)
    fleet.kill(2)
    assert fleet.detect_dead() == [2]
    assert fleet.n_chips == 6
    fleet.decommission(2)
    # a decommissioned host never re-triggers detection
    assert fault.detect_failures(board.read_all(), fleet.t + 100,
                                 timeout_s=3.0) == [0, 1, 3]


def test_fault_plan_validation_and_determinism():
    with pytest.raises(ValueError):
        fault.FaultEvent(step=0, kind="kill")
    with pytest.raises(ValueError):
        fault.FaultEvent(step=1, kind="meteor")
    with pytest.raises(ValueError):
        fault.FaultPlan((fault.FaultEvent(2, "kill"),
                         fault.FaultEvent(2, "device_loss")))
    p1 = fault.make_fault_plan(3, 20, n_faults=3)
    p2 = fault.make_fault_plan(3, 20, n_faults=3)
    assert p1 == p2
    steps = [e.step for e in p1.events]
    assert steps == sorted(steps)
    assert min(abs(a - b) for i, a in enumerate(steps)
               for b in steps[i + 1:]) >= 2
    kinds = sorted(e.kind for e in p1.events)
    assert kinds == ["device_loss", "kill", "straggler"]
    # injected stragglers must be detectable at the default factor
    assert all(e.severity >= 4 for e in p1.events if e.kind == "straggler")


# ------------------------------------------------------ drill end-to-end
def _drill_setup():
    arch = get_config("qwen2-1.5b").reduced().replace(n_layers=2)
    pipe = SyntheticLM(DataConfig(global_batch=2, seq_len=16,
                                  vocab_size=arch.vocab_size, seed=3))
    tcfg = TrainConfig(steps=6,
                       opt=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                           total_steps=6))
    return arch, pipe, tcfg


def test_drill_end_to_end_detects_recovers_bit_identical(tmp_path):
    arch, pipe, tcfg = _drill_setup()
    plan = fault.FaultPlan((
        fault.FaultEvent(step=1, kind="straggler", severity=4),
        fault.FaultEvent(step=2, kind="kill"),
        fault.FaultEvent(step=4, kind="device_loss"),
    ))

    def drill(p, wd):
        dcfg = DrillConfig(workdir=str(tmp_path / wd), steps=6,
                           local_every=1, durable_every=3,
                           n_hosts=4, n_chips=8)
        return Supervisor(arch, tcfg, dcfg, pipe, p, seed=0).run_drill()

    rep = drill(plan, "drill")
    base = drill(fault.FaultPlan(()), "base")

    # every injected fault detected; the run still finishes
    assert rep["faults_injected"] == rep["faults_detected"] == 3
    assert rep["fault_kill"] == rep["fault_device_loss"] == 1
    assert rep["fault_straggler"] == 1
    assert rep["final_step"] == 6
    assert rep["attempts"] == 3           # two restart-class faults
    assert rep["steps_recomputed"] > 0
    # kill restores from the fast local tier; device loss invalidates it
    # and falls back to durable, resuming elastically on fewer chips
    assert rep["restore_local"] == 1
    assert rep["restore_durable"] == 1
    assert rep["remesh_events"] == 1
    assert rep["dp_width_final"] < rep["dp_width_initial"]
    # recomputed trajectory is bit-identical to the uninterrupted run
    assert rep["losses"] == base["losses"]
    assert base["steps_recomputed"] == 0 and base["attempts"] == 1
    # ledger partition holds on the real clock too
    g = rep["goodput"]
    assert sum(g["buckets_s"].values()) == pytest.approx(g["wall_s"])
    assert 0 < g["goodput_pct"] < 100

    pr = price_drill(arch, rep, tokens_per_step=2 * 16)
    assert pr["tokens_computed"] > pr["tokens_useful"]
    assert pr["pj_per_useful_token"] > pr["pj_per_token"]
    # baseline has no BadPut to price
    pb = price_drill(arch, base, tokens_per_step=2 * 16)
    assert pb["pj_per_useful_token"] == pytest.approx(pb["pj_per_token"])


def test_drill_survives_fault_before_first_cadence_save(tmp_path):
    # a kill at step 1 lands before any cadence checkpoint: the init
    # (step 0) durable floor must catch it and the run recomputes from 0
    arch, pipe, tcfg = _drill_setup()
    plan = fault.FaultPlan((fault.FaultEvent(step=1, kind="kill"),))
    dcfg = DrillConfig(workdir=str(tmp_path), steps=3,
                       local_every=10, durable_every=10,
                       n_hosts=4, n_chips=8)
    tcfg = TrainConfig(steps=3, opt=tcfg.opt)
    rep = Supervisor(arch, tcfg, dcfg, pipe, plan, seed=0).run_drill()
    assert rep["final_step"] == 3
    assert rep["faults_detected"] == 1
    assert rep["restore_durable"] == 1
    assert rep["steps_recomputed"] == 1   # step 0 re-run from the floor


# ------------------------------------------ trainer metrics parity (MoE)
def test_microbatched_aux_loss_survives():
    arch = get_config("grok-1-314b").reduced().replace(n_layers=2)
    params = init_params(jax.random.PRNGKey(0), arch)
    batch = SyntheticLM(DataConfig(global_batch=4, seq_len=32,
                                   vocab_size=arch.vocab_size)).batch_at(0)
    o = init_opt_state(params, OptimizerConfig())
    _, _, m1 = make_train_step(arch, TrainConfig(microbatches=1))(
        params, o, batch)
    _, _, m2 = make_train_step(arch, TrainConfig(microbatches=2))(
        params, o, batch)
    a1, a2 = float(m1["aux_loss"]), float(m2["aux_loss"])
    # the scan path used to hardcode aux_loss = 0
    assert a2 > 0.0
    # per-microbatch load-balance terms differ slightly from the full
    # batch's (expert assignment is batch-dependent) but must agree to
    # ~10%, not vanish
    assert abs(a2 - a1) / a1 < 0.1
    assert float(m2["loss"]) == pytest.approx(float(m1["loss"]), rel=0.05)
