"""Sampling contract at the prefill seam: ``temperature > 0`` samples
only when a PRNG key is passed; ``temperature > 0`` WITHOUT a key warns
(``UserWarning``) and falls back to greedy argmax — the explicit form of
what used to happen silently (the first token's logits never saw the
temperature path without a key, so callers believed they were sampling
and got argmax)."""
import warnings

import jax
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig
from repro.serving.scheduler import Scheduler, SchedulerConfig, StepClock

_CACHE = {}


def _engine(temperature, slots=1, ctx=32):
    if "ap" not in _CACHE:
        arch = get_config("qwen2-1.5b").reduced()
        _CACHE["ap"] = (arch, init_params(jax.random.PRNGKey(0), arch))
    arch, params = _CACHE["ap"]
    return Engine(arch, params,
                  ServeConfig(batch_slots=slots, max_ctx=ctx,
                              temperature=temperature))

PROMPT = [3, 1, 4, 1, 5]


def test_temperature_with_key_samples_without_warning():
    """temp > 0 + key: the sampled path runs silently and is reproducible
    under the same key."""
    firsts = []
    for _ in range(2):
        eng = _engine(temperature=1.0)
        with warnings.catch_warnings():
            warnings.simplefilter("error")   # any warning fails the test
            slot = eng.add_request(PROMPT, key=jax.random.PRNGKey(7))
        firsts.append(eng.tokens[slot][-1])
    assert firsts[0] == firsts[1]


def test_temperature_without_key_warns_and_is_greedy():
    """temp > 0, no key: a UserWarning fires and the emitted token equals
    the greedy (temperature=0) engine's — documented fallback, not a
    silent one."""
    eng_t = _engine(temperature=1.0)
    with pytest.warns(UserWarning, match="falling back to greedy"):
        slot = eng_t.add_request(PROMPT)
    eng_g = _engine(temperature=0.0)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # greedy path must not warn
        slot_g = eng_g.add_request(PROMPT)
    assert eng_t.tokens[slot][-1] == eng_g.tokens[slot_g][-1]


def test_incremental_prefill_applies_same_contract():
    """The scheduler's seam (``finish_prefill``) enforces the identical
    rule: warns keyless under temperature, silent with a key."""
    eng = _engine(temperature=0.7)
    slot = eng.begin_request(PROMPT)
    while eng.prefill_remaining(slot):
        eng.advance_prefill(slot)
    with pytest.warns(UserWarning, match="falling back to greedy"):
        eng.finish_prefill(slot)

    eng2 = _engine(temperature=0.7)
    slot2 = eng2.begin_request(PROMPT)
    while eng2.prefill_remaining(slot2):
        eng2.advance_prefill(slot2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng2.finish_prefill(slot2, key=jax.random.PRNGKey(3))


def test_scheduler_threads_keys_per_request():
    """Scheduler.step(key=...) folds a per-request sub-key into every
    finish_prefill, so a temperature engine under the scheduler samples
    without warnings and reproducibly."""
    def run():
        clock = StepClock()
        eng = _engine(temperature=1.0, slots=2)
        sched = Scheduler(eng, SchedulerConfig(), clock=clock.now)
        rs = [sched.submit(PROMPT, max_new_tokens=4, arrival=0.0)
              for _ in range(2)]
        key = jax.random.PRNGKey(11)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            steps = 0
            while not sched.idle():
                key, sub = jax.random.split(key)
                sched.step(sub)
                clock.tick()
                steps += 1
                assert steps < 100
        return [r.generated for r in rs]

    assert run() == run()
