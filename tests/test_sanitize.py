"""Opt-in numerics sanitizer (``REPRO_SANITIZE=1``): the GR-MAC backends
stage in-graph nonfinite / pre-ADC-overflow / gain-range-limit checks, and
stage NOTHING when the flag is unset — structurally zero-cost (no extra
jaxpr primitives, bit-identical outputs), not merely disabled."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import sanitize
from repro.core.formats import FP4_E2M1, FP6_E3M2, FP8_E4M3, quantize
from repro.kernels.dispatch import grmac_matmul


def _kw(**over):
    kw = dict(fmt_x=FP6_E3M2, fmt_w=FP4_E2M1, n_r=32, enob=8.0,
              granularity="row", backend="xla")
    kw.update(over)
    return kw


def _data(key, m, k, n, *, narrow=False):
    """Uniform operands; ``narrow=True`` confines magnitudes to [0.5, 1)
    (a single binade), so every gain-range span is well inside the limit
    and the compute line stays inside ADC full scale."""
    kx, kw_ = jax.random.split(jax.random.PRNGKey(key))
    lo = 0.5 if narrow else -1.0
    x = jax.random.uniform(kx, (m, k), minval=lo, maxval=1.0)
    if narrow:
        sgn = jnp.sign(jax.random.uniform(kw_, (m, k)) - 0.5)
        x = sgn * x
    w = quantize(jax.random.uniform(kw_, (k, n), minval=lo, maxval=1.0),
                 FP4_E2M1)
    return x, w


@pytest.fixture
def _clean(monkeypatch):
    monkeypatch.delenv(sanitize.ENV_VAR, raising=False)
    sanitize.clear()
    yield
    sanitize.clear()


def test_zero_cost_when_unset(_clean, monkeypatch):
    """No REPRO_SANITIZE: zero extra jaxpr primitives, and the output is
    bit-identical to an explicit '0' (the two off spellings share a plan)."""
    x, w = _data(0, 64, 64, 16)
    jaxpr = jax.make_jaxpr(lambda a, b: grmac_matmul(a, b, **_kw()))(x, w)
    assert "debug_callback" not in str(jaxpr)
    out_unset = np.asarray(grmac_matmul(x, w, **_kw()))
    monkeypatch.setenv(sanitize.ENV_VAR, "0")
    out_zero = np.asarray(grmac_matmul(x, w, **_kw()))
    np.testing.assert_array_equal(out_unset, out_zero)
    assert sanitize.VIOLATIONS == []


def test_sanitize_on_is_bit_identical_and_clean(_clean, monkeypatch):
    """Instrumentation must never change numerics, and well-conditioned
    (single-binade) operands must report nothing on any backend."""
    x, w = _data(1, 64, 64, 16, narrow=True)
    baselines = {g: np.asarray(grmac_matmul(x, w, **_kw(granularity=g)))
                 for g in ("conv", "row", "unit")}
    monkeypatch.setenv(sanitize.ENV_VAR, "1")
    jaxpr = jax.make_jaxpr(
        lambda a, b: grmac_matmul(a, b, tag="t", **_kw()))(x, w)
    assert "debug_callback" in str(jaxpr)   # the checks really staged
    for backend in ("xla", "tiled", "ref"):
        for g in ("conv", "row", "unit"):
            out = grmac_matmul(x, w, tag=f"{backend}/{g}",
                               **_kw(granularity=g, backend=backend))
            np.testing.assert_array_equal(np.asarray(out), baselines[g])
    jax.effects_barrier()
    assert sanitize.VIOLATIONS == [], sanitize.VIOLATIONS


@pytest.mark.parametrize("backend", ["xla", "tiled", "ref"])
def test_nan_input_is_caught(_clean, monkeypatch, backend):
    monkeypatch.setenv(sanitize.ENV_VAR, "1")
    x, w = _data(2, 16, 64, 8)
    x = x.at[0, 0].set(jnp.nan)
    out = grmac_matmul(x, w, tag=f"nan/{backend}",
                       **_kw(backend=backend))
    jax.block_until_ready(out)
    jax.effects_barrier()
    recs = [v for v in sanitize.VIOLATIONS if v["kind"] == "nonfinite"]
    assert recs, sanitize.VIOLATIONS
    assert recs[0]["tag"] == f"nan/{backend}"
    assert recs[0]["count"] >= 1


def test_gain_range_violation_is_caught(_clean, monkeypatch):
    """FP8_E4M3 activations over full-range uniform data span more
    exponent bits than GAIN_RANGE_LIMIT_BITS per row block: statically the
    format is on the feasibility wall, and the sanitizer sees the actual
    operands cross it."""
    monkeypatch.setenv(sanitize.ENV_VAR, "1")
    x, w = _data(0, 64, 64, 16)
    out = grmac_matmul(x, w, tag="gain/e4m3",
                       **_kw(fmt_x=FP8_E4M3))
    jax.block_until_ready(out)
    jax.effects_barrier()
    recs = [v for v in sanitize.VIOLATIONS if v["kind"] == "gain_range"]
    assert recs, sanitize.VIOLATIONS
    assert recs[0]["tag"] == "gain/e4m3"
    assert recs[0]["worst"] > 6          # beyond the C-2C ladder depth


def test_env_is_read_per_call(_clean, monkeypatch):
    """Flipping the env var mid-process takes effect on the next call —
    no import-time staleness."""
    # NaN, not Inf: Inf is clamped onto the format grid during operand
    # decomposition and never reaches the compute line; NaN propagates
    x, w = _data(3, 16, 64, 8)
    x = x.at[0, 0].set(jnp.nan)
    monkeypatch.setenv(sanitize.ENV_VAR, "1")
    jax.block_until_ready(grmac_matmul(x, w, tag="flip", **_kw()))
    jax.effects_barrier()
    assert sanitize.VIOLATIONS
    sanitize.clear()
    monkeypatch.setenv(sanitize.ENV_VAR, "0")
    jax.block_until_ready(grmac_matmul(x, w, tag="flip", **_kw()))
    jax.effects_barrier()
    assert sanitize.VIOLATIONS == []
