"""Training substrate: optimizer, pipeline determinism, checkpoint/restart,
fault detection, elastic planning, gradient compression, end-to-end loss
descent on a tiny model."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params
from repro.training import checkpoint as ckpt
from repro.training import fault
from repro.training.optimizer import (
    OptimizerConfig,
    apply_updates,
    compress_grads,
    decompress_grads,
    init_opt_state,
    schedule,
)
from repro.training.trainer import TrainConfig, make_train_step, train


# ---------------------------------------------------------------- optimizer
def test_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    s0 = float(schedule(cfg, jnp.asarray(0)))
    s10 = float(schedule(cfg, jnp.asarray(10)))
    s100 = float(schedule(cfg, jnp.asarray(100)))
    assert s0 < s10
    assert abs(s10 - 1e-3) < 1e-9
    assert s100 < s10


def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0)
    state = init_opt_state(params, cfg)
    for _ in range(150):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, state, m = apply_updates(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_compression_error_feedback_bounded(seed):
    g = {"a": jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0}
    err = jax.tree.map(jnp.zeros_like, g)
    q, scales, err2 = compress_grads(g, err)
    rec = decompress_grads(q, scales)
    # per-tensor int8: error bounded by scale/2, and captured in residual
    scale = float(scales["a"])
    assert float(jnp.max(jnp.abs(rec["a"] + err2["a"] - g["a"]))) < 1e-5
    assert float(jnp.max(jnp.abs(rec["a"] - g["a"]))) <= scale / 2 + 1e-6


# ---------------------------------------------------------------- pipeline
def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(global_batch=4, seq_len=16, vocab_size=100, seed=7)
    p1, p2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b5a, b5b = p1.batch_at(5), p2.batch_at(5)
    np.testing.assert_array_equal(b5a["inputs"], b5b["inputs"])
    assert not np.array_equal(p1.batch_at(6)["inputs"], b5a["inputs"])
    assert int(jnp.max(b5a["inputs"])) < 100
    np.testing.assert_array_equal(
        np.asarray(b5a["inputs"][:, 1:]), np.asarray(b5a["labels"][:, :-1]))


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    d = str(tmp_path / "ckpt")
    ckpt.save_checkpoint(d, 3, tree)
    ckpt.save_checkpoint(d, 7, jax.tree.map(lambda x: x * 2, tree))
    assert ckpt.latest_step(d) == 7
    got, step = ckpt.restore_checkpoint(d, tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree["a"]) * 2)
    # corrupt a file -> restore must fail loudly
    target = None
    for fn in os.listdir(os.path.join(d, "step_000000007")):
        if fn.endswith(".npy"):
            target = os.path.join(d, "step_000000007", fn)
            break
    with open(target, "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x01\x02")
    with pytest.raises(IOError):
        ckpt.restore_checkpoint(d, tree, step=7)
    # previous step still loads
    got3, step3 = ckpt.restore_checkpoint(d, tree, step=3)
    assert step3 == 3


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"x": jnp.zeros((2,))}
    for s in range(6):
        ckpt.save_checkpoint(d, s, tree, keep=2)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d))
    assert steps == [4, 5]


# ---------------------------------------------------------------- fault
def test_failure_and_straggler_detection():
    now = 1000.0
    beats = [fault.Heartbeat(h, 10, now - (100.0 if h == 2 else 1.0), 1.0)
             for h in range(4)]
    beats[3].step_time_s = 10.0
    assert fault.detect_failures(beats, now, timeout_s=60) == [2]
    assert fault.detect_failures(beats, now, timeout_s=60,
                                 expected_hosts=6) == [2, 4, 5]
    assert fault.detect_stragglers(beats) == [3]


def test_heartbeat_board(tmp_path):
    board = fault.HeartbeatBoard(str(tmp_path / "hb"))
    board.beat(fault.Heartbeat(0, 5, time.time(), 0.5))
    board.beat(fault.Heartbeat(1, 5, time.time(), 0.6))
    got = board.read_all()
    assert sorted(b.host for b in got) == [0, 1]


def test_plan_remesh():
    shape, axes = fault.plan_remesh(512, 16)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    # lose a pod's worth: fall back to single-pod style mesh
    shape, axes = fault.plan_remesh(256, 16)
    assert shape == (16, 16) and axes == ("data", "model")
    # odd survivor count: largest DP multiple
    shape, axes = fault.plan_remesh(250, 16)
    assert shape[-2] * shape[-1] <= 250 and shape[-1] == 16
    with pytest.raises(RuntimeError):
        fault.plan_remesh(8, 16)


# ---------------------------------------------------------------- end-to-end
def test_train_descends_and_restarts(tmp_path):
    arch = get_config("qwen2-1.5b").reduced().replace(n_layers=2)
    dcfg = DataConfig(global_batch=4, seq_len=32, vocab_size=arch.vocab_size,
                      seed=1)
    pipe = SyntheticLM(dcfg)
    ckdir = str(tmp_path / "ck")
    tcfg = TrainConfig(
        steps=6, ckpt_dir=ckdir, ckpt_every=3, log_every=100,
        opt=OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=6))
    train(arch, tcfg, pipe, seed=0)
    assert ckpt.latest_step(ckdir) == 6
    # "crash" after step 6, extend run, resume from checkpoint
    tcfg2 = TrainConfig(
        steps=8, ckpt_dir=ckdir, ckpt_every=3, log_every=100,
        opt=OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=8))
    m2 = train(arch, tcfg2, pipe, seed=0)
    assert np.isfinite(m2["loss"])


def test_train_step_microbatched_matches_full():
    arch = get_config("stablelm-3b").reduced().replace(n_layers=2)
    params = init_params(jax.random.PRNGKey(0), arch)
    dcfg = DataConfig(global_batch=4, seq_len=32, vocab_size=arch.vocab_size)
    batch = SyntheticLM(dcfg).batch_at(0)
    o1 = init_opt_state(params, OptimizerConfig())
    t1 = TrainConfig(microbatches=1)
    t2 = TrainConfig(microbatches=2)
    p1, _, m1 = make_train_step(arch, t1)(params, o1, batch)
    o2 = init_opt_state(params, OptimizerConfig())
    p2, _, m2 = make_train_step(arch, t2)(params, o2, batch)
    # same data -> same gradients (up to accumulation order): params close
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-2, d


def test_grad_compression_trains():
    arch = get_config("stablelm-3b").reduced().replace(n_layers=2)
    params = init_params(jax.random.PRNGKey(0), arch)
    ocfg = OptimizerConfig(grad_compression=True, lr=1e-3)
    ostate = init_opt_state(params, ocfg)
    tcfg = TrainConfig(opt=ocfg)
    step = make_train_step(arch, tcfg)
    dcfg = DataConfig(global_batch=4, seq_len=32, vocab_size=arch.vocab_size)
    pipe = SyntheticLM(dcfg)
    losses = []
    for s in range(5):
        params, ostate, m = step(params, ostate, pipe.batch_at(s))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 1.2  # descends (noisy small-scale)


def test_adafactor_descends_and_state_is_small():
    from repro.training.optimizer import apply_updates as au
    params = {"w": jnp.ones((64, 32)), "b": jnp.zeros((32,))}
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0, algorithm="adafactor")
    st = init_opt_state(params, cfg)
    # factored second moment: O(rows+cols), not O(rows*cols)
    assert st["vr"]["w"].shape == (64,)
    assert st["vc"]["w"].shape == (32,)
    p = params
    for _ in range(80):
        g = jax.tree.map(lambda x: 2 * x, p)
        p, st, _ = au(p, g, st, cfg)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.3


def test_adafactor_trains_tiny_model():
    arch = get_config("stablelm-3b").reduced().replace(n_layers=2)
    ocfg = OptimizerConfig(lr=1e-3, algorithm="adafactor")
    params = init_params(jax.random.PRNGKey(0), arch)
    state = init_opt_state(params, ocfg)
    step = make_train_step(arch, TrainConfig(opt=ocfg))
    pipe = SyntheticLM(DataConfig(global_batch=4, seq_len=32,
                                  vocab_size=arch.vocab_size))
    losses = []
    for s in range(6):
        params, state, m = step(params, state, pipe.batch_at(s))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_elastic_end_to_end(tmp_path):
    """Full failure-recovery cycle: train -> checkpoint -> lose chips ->
    plan a smaller mesh -> reshard -> resume training losslessly."""
    arch = get_config("stablelm-3b").reduced().replace(n_layers=2)
    dcfg = DataConfig(global_batch=4, seq_len=32, vocab_size=arch.vocab_size)
    pipe = SyntheticLM(dcfg)
    ckdir = str(tmp_path / "ck")
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=8)
    tcfg = TrainConfig(steps=4, ckpt_dir=ckdir, ckpt_every=2, log_every=100,
                       opt=ocfg)
    train(arch, tcfg, pipe, seed=0)

    # simulate: only 1 "chip" survives; plan keeps model_parallel=1
    shape, axes = fault.plan_remesh(1, 1, pod_size=256)
    assert shape == (1, 1) and axes == ("data", "model")
    from repro.compat import make_mesh
    mesh = make_mesh(shape, axes)
    params = init_params(jax.random.PRNGKey(0), arch)
    opt = init_opt_state(params, ocfg)
    state, step = ckpt.restore_checkpoint(
        ckdir, {"params": params, "opt": opt})
    from repro.parallel.sharding import param_specs
    specs = {"params": param_specs(params, mesh),
             "opt": param_specs(opt, mesh)}
    resharded = fault.reshard_tree(state, mesh, specs)
    assert step == 4
    # resume two more steps on the new mesh
    stepper = make_train_step(arch, TrainConfig(opt=ocfg))
    p, o = resharded["params"], resharded["opt"]
    for s in range(step, step + 2):
        p, o, m = stepper(p, o, pipe.batch_at(s))
        assert np.isfinite(float(m["loss"]))
