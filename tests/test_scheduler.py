"""Continuous-batching scheduler (``repro.serving.scheduler``): FIFO
admission and state machine, scheduler/hand-placed dispatch equivalence
across the four cache families, budgeted prefill interleaving, admission
control, preemption-resume stream invariance, static-batching baseline
semantics, pJ/token threading, and traffic determinism.

Everything runs greedy on the virtual ``StepClock`` unless a test says
otherwise, so token streams and schedules are deterministic."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig
from repro.serving.scheduler import (
    FINISHED,
    PREFILLING,
    RUNNING,
    WAITING,
    Scheduler,
    SchedulerConfig,
    StaticBatchScheduler,
    StepClock,
    run_closed_loop,
    run_open_loop,
    synth_shared_prefix_traffic,
    synth_traffic,
)

# the four cache families the engine serves (attention KV, RG-LRU
# recurrent, SSM state, MoE routed) — the equivalence contract must hold
# on all of them
FAMILIES = [
    ("attn", "qwen2-1.5b"),
    ("rglru", "recurrentgemma-9b"),
    ("ssm", "mamba2-1.3b"),
    ("moe", "grok-1-314b"),
]

_CACHE = {}


def _arch_params(name="qwen2-1.5b"):
    if name not in _CACHE:
        arch = get_config(name).reduced()
        _CACHE[name] = (arch, init_params(jax.random.PRNGKey(0), arch))
    return _CACHE[name]


def _engine(name="qwen2-1.5b", slots=2, ctx=64, **cfg_kw):
    arch, params = _arch_params(name)
    return Engine(arch, params,
                  ServeConfig(batch_slots=slots, max_ctx=ctx, **cfg_kw))


def _drain(sched, clock, max_steps=500):
    steps = 0
    while not sched.idle():
        sched.step()
        clock.tick()
        steps += 1
        assert steps < max_steps, "scheduler failed to drain"
    return steps


def _sched(eng, clock, **cfg_kw):
    return Scheduler(eng, SchedulerConfig(**cfg_kw), clock=clock.now)


def test_fifo_admission_and_state_machine():
    """More requests than slots: the first two claim slots FIFO, the
    third waits, takes the first freed slot, and every request walks
    WAITING -> (PREFILLING) -> RUNNING -> FINISHED."""
    clock = StepClock()
    sched = _sched(_engine(slots=2), clock)
    rs = [sched.submit([3, 1, 4, 1, 5], max_new_tokens=3, arrival=0.0)
          for _ in range(3)]
    assert [r.state for r in rs] == [WAITING] * 3

    sched.step()
    clock.tick()
    assert rs[0].state == RUNNING and rs[1].state == RUNNING
    assert rs[2].state == WAITING         # no free slot yet
    assert (rs[0].slot, rs[1].slot) == (0, 1)
    assert rs[0].t_admit is not None and rs[2].t_admit is None

    _drain(sched, clock)
    assert [r.state for r in rs] == [FINISHED] * 3
    assert [r.finish_reason for r in rs] == ["length"] * 3
    assert [r.n_generated for r in rs] == [3, 3, 3]
    # FIFO: the late request was admitted only after a slot freed
    assert rs[2].t_admit > rs[0].t_admit
    assert sched.metrics()["completed"] == 3


def test_token_mode_engine_is_rejected():
    eng = _engine(prefill_mode="token")
    with pytest.raises(ValueError, match="bucketed"):
        Scheduler(eng)


@pytest.mark.parametrize("family,name", FAMILIES)
def test_scheduler_matches_hand_placed_engine(family, name):
    """Under fixed, non-overflowing arrivals and an unbounded prefill
    budget the scheduler must be dispatch-for-dispatch identical to
    hand-placed ``add_request``/``step`` calls: same token streams, same
    prefill chunk count, same decode step count."""
    prompts = [[3, 1, 4, 1, 5], [2, 7, 1, 8, 2, 8, 1, 8, 2, 8, 4]]
    n_new = 5

    clock = StepClock()
    eng_s = _engine(name, slots=2)
    sched = _sched(eng_s, clock, prefill_token_budget=None)
    rs = [sched.submit(p, max_new_tokens=n_new, arrival=0.0)
          for p in prompts]
    _drain(sched, clock)

    eng_h = _engine(name, slots=2)
    slots = [eng_h.add_request(p) for p in prompts]
    for _ in range(n_new - 1):          # first token came from prefill
        eng_h.step()

    for r, p, slot in zip(rs, prompts, slots):
        hand = eng_h.tokens[slot][len(p):len(p) + n_new]
        assert r.generated == hand, f"{family}: stream diverged"
    assert (eng_s.stats["prefill_dispatches"]
            == eng_h.stats["prefill_dispatches"])
    assert eng_s.stats["decode_steps"] == eng_h.stats["decode_steps"]


def test_prefill_budget_interleaves_across_steps():
    """A 12-token prompt under a 4-token budget drains across three
    scheduler steps (PREFILLING throughout, no decode yet), and TTFT is
    stamped at the step the prompt completes."""
    clock = StepClock()
    eng = _engine(slots=2)
    sched = _sched(eng, clock, prefill_token_budget=4)
    r = sched.submit(list(range(1, 13)), max_new_tokens=3, arrival=0.0)

    sched.step(); clock.tick()
    assert r.state == PREFILLING
    assert eng.prefill_remaining(r.slot) == 8
    assert eng.stats["decode_steps"] == 0
    sched.step(); clock.tick()
    assert r.state == PREFILLING and eng.prefill_remaining(r.slot) == 4
    assert r.t_first is None
    sched.step(); clock.tick()
    assert r.state == RUNNING           # drained + first token this step
    assert r.t_first is not None and r.n_generated == 2  # first + 1 decode
    _drain(sched, clock)
    assert r.finish_reason == "length" and r.n_generated == 3


def test_budget_spends_fifo_across_requests():
    """One step's budget spreads FIFO over the prefilling queue: the
    head's remainder drains before the next request gets chunks."""
    clock = StepClock()
    eng = _engine(slots=2)
    sched = _sched(eng, clock, prefill_token_budget=8)
    r0 = sched.submit(list(range(1, 13)), max_new_tokens=4, arrival=0.0)
    r1 = sched.submit(list(range(1, 13)), max_new_tokens=4, arrival=0.0)
    sched.step()                        # 8 tokens -> all to r0
    assert eng.prefill_remaining(r0.slot) == 4
    assert eng.prefill_remaining(r1.slot) == 12
    sched.step()                        # 4 to finish r0, 4 to r1
    assert r0.state == RUNNING
    assert eng.prefill_remaining(r1.slot) == 8


def test_admission_rejects_prompt_that_cannot_fit():
    clock = StepClock()
    sched = _sched(_engine(slots=2, ctx=32), clock)
    r_big = sched.submit(list(range(1, 40)), max_new_tokens=4, arrival=0.0)
    r_ok = sched.submit([5, 6, 7], max_new_tokens=2, arrival=0.0)
    _drain(sched, clock)
    assert r_big.state == FINISHED and r_big.finish_reason == "rejected"
    assert r_big.n_generated == 0 and r_big.t_admit is None
    assert r_ok.finish_reason == "length"
    m = sched.metrics()
    assert m["rejected"] == 1 and m["completed"] == 1


def test_max_new_tokens_one_finishes_at_prefill():
    """max_new_tokens=1 completes on the prefill-sampled token without
    ever joining the decode batch; the slot frees immediately."""
    clock = StepClock()
    eng = _engine(slots=1)
    sched = _sched(eng, clock)
    r = sched.submit([3, 1, 4], max_new_tokens=1, arrival=0.0)
    sched.step()
    assert r.state == FINISHED and r.finish_reason == "length"
    assert r.n_generated == 1
    assert eng.stats["decode_steps"] == 0
    assert eng.free_slots() == 1


def test_eos_finish_reason(monkeypatch):
    """A scripted EOS on the second token finishes the request with
    reason 'eos' and frees the slot (ids scripted through the engine's
    single ``_fetch`` seam, as in test_serving_eos)."""
    script = [[5], [9], [7]]
    it = {"t": 0}

    def fake_fetch(ids_dev):
        row = script[min(it["t"], len(script) - 1)]
        it["t"] += 1
        return np.asarray(row, np.int32)

    monkeypatch.setattr(Engine, "_fetch", staticmethod(fake_fetch))
    clock = StepClock()
    eng = _engine(slots=1)
    sched = _sched(eng, clock)
    r = sched.submit([3, 1, 4], max_new_tokens=10, eos_id=7, arrival=0.0)
    _drain(sched, clock)
    assert r.finish_reason == "eos"
    assert r.generated == [5, 9, 7]     # EOS kept, nothing after
    assert eng.free_slots() == 1


def test_preemption_resume_stream_is_invariant():
    """Anti-starvation preemption with recompute resume: the preempted
    greedy request's final token stream must equal an uninterrupted run
    — the re-prefilled prompt+generated reconstructs the cache exactly."""
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]
    n_new = 6

    # reference: alone on the engine, never preempted
    clock = StepClock()
    ref = _sched(_engine(slots=1), clock, prefill_token_budget=None)
    r_ref = ref.submit(prompt, max_new_tokens=n_new, arrival=0.0)
    _drain(ref, clock)

    clock = StepClock()
    sched = _sched(_engine(slots=1), clock, prefill_token_budget=None,
                   preempt_age=2.0)
    r0 = sched.submit(prompt, max_new_tokens=n_new, arrival=0.0)
    r1 = sched.submit([2, 7, 1], max_new_tokens=2, arrival=0.0)
    _drain(sched, clock)

    assert r0.preemptions == 1
    assert r1.preemptions == 0          # victim is the newest admit (r0
    # was alone when r1's wait aged out... the LIFO victim is whichever
    # holds the slot: r0)
    assert r0.finish_reason == "length" and r0.n_generated == n_new
    assert r0.generated == r_ref.generated
    assert sched.metrics()["preempted"] == 1
    # preempted-and-resumed requests are admitted twice
    assert sched.stats["admitted"] == 3


def test_static_batching_blocks_until_batch_drains():
    """The baseline admits a new batch only when the previous one fully
    drains: the third request waits for BOTH in-flight requests even
    though a slot freed much earlier. The continuous scheduler admits it
    as soon as the first slot frees."""
    def run(cls):
        clock = StepClock()
        sched = cls(_engine(slots=2), clock=clock.now)
        rs = [sched.submit([3, 1, 4], max_new_tokens=n, arrival=0.0)
              for n in (2, 8, 2)]
        _drain(sched, clock)
        return rs

    static = run(StaticBatchScheduler)
    assert static[2].t_admit > static[1].t_finish   # waited for straggler
    cont = run(Scheduler)
    assert cont[2].t_admit < static[2].t_admit
    assert cont[2].t_admit <= cont[0].t_finish + 1.0  # freed slot reused
    for r in static + cont:
        assert r.finish_reason == "length"


def test_pj_per_token_threads_from_step_result(monkeypatch):
    monkeypatch.setattr(Engine, "_pj_per_token", lambda self: 42.0)
    clock = StepClock()
    sched = _sched(_engine(slots=1), clock)
    assert sched.pj_per_token is None   # no decode step yet
    sched.submit([3, 1, 4], max_new_tokens=3, arrival=0.0)
    _drain(sched, clock)
    assert sched.pj_per_token == 42.0
    m = sched.metrics()
    assert m["pj_per_token"] == 42.0
    assert m["energy_pj"] == 42.0 * m["generated_tokens"]


def test_synth_traffic_seeded_and_rate_invariant():
    a = synth_traffic(8, 0.5, seed=3, vocab_size=100)
    b = synth_traffic(8, 0.5, seed=3, vocab_size=100)
    assert [t.arrival for t in a] == [t.arrival for t in b]
    assert [t.prompt for t in a] == [t.prompt for t in b]
    assert [t.max_new_tokens for t in a] == [t.max_new_tokens for t in b]
    # rate scales arrival times only: same pattern, same lengths
    c = synth_traffic(8, 1.0, seed=3, vocab_size=100)
    np.testing.assert_allclose([t.arrival for t in c],
                               [t.arrival / 2 for t in a])
    assert [t.prompt for t in c] == [t.prompt for t in a]


def test_shortest_prompt_admission_order():
    """admission="shortest_prompt" on one slot admits by effective
    prompt length (shortest first), counting each out-of-FIFO-order
    pick; FIFO on the same workload admits in arrival order."""
    def run(**kw):
        clock = StepClock()
        sched = _sched(_engine(slots=1), clock, **kw)
        rs = [sched.submit(list(range(1, n + 1)), max_new_tokens=2,
                           arrival=0.0) for n in (12, 6, 3)]
        _drain(sched, clock)
        return sched, rs

    sched, (r_long, r_mid, r_short) = run(admission="shortest_prompt")
    assert r_short.t_admit < r_mid.t_admit < r_long.t_admit
    assert sched.stats["admission_reorders"] == 2
    for r in (r_long, r_mid, r_short):
        assert r.finish_reason == "length" and r.n_generated == 2

    sched, rs = run()                   # FIFO control
    assert rs[0].t_admit < rs[1].t_admit < rs[2].t_admit
    assert sched.stats["admission_reorders"] == 0
    assert sched.metrics()["admission_reorders"] == 0


def test_admission_age_bound_stops_starvation():
    """Once the queue head has aged past ``admission_age_bound`` it is
    admitted first even though shorter prompts are waiting."""
    clock = StepClock()
    sched = _sched(_engine(slots=1), clock, admission="shortest_prompt",
                   admission_age_bound=0.5)
    r_long = sched.submit(list(range(1, 13)), max_new_tokens=2,
                          arrival=0.0)
    shorts = [sched.submit([7, 8, 9], max_new_tokens=2, arrival=0.0)
              for _ in range(3)]
    _drain(sched, clock)
    # the first admission (at t=0, head not yet aged) goes to a short;
    # by the next free slot (t=1) the head is past the bound and jumps
    # the remaining shorts
    assert shorts[0].t_admit < r_long.t_admit
    assert r_long.t_admit < shorts[1].t_admit < shorts[2].t_admit
    assert sched.stats["admission_reorders"] == 1


def test_unknown_admission_policy_rejected():
    with pytest.raises(ValueError, match="admission"):
        Scheduler(_engine(), SchedulerConfig(admission="sjf"))


def test_closed_loop_holds_concurrency_and_is_deterministic():
    """The closed-loop driver keeps at most ``concurrency`` requests in
    flight (submitted minus finished) and drains the whole trace; two
    runs over the same traffic are identical."""
    arch, _ = _arch_params()
    traffic = synth_traffic(6, 0.3, seed=1, vocab_size=arch.vocab_size,
                            prompt_len=(3, 12), out_len=(2, 5))

    def run():
        clock = StepClock()
        sched = _sched(_engine(slots=2), clock, prefill_token_budget=6)
        in_flight_max = [0]

        def tick(cost=1.0):
            live = (len(sched.waiting) + len(sched.prefilling)
                    + len(sched.running))
            in_flight_max[0] = max(in_flight_max[0], live)
            clock.tick(cost)

        run_closed_loop(sched, traffic, concurrency=2, tick=tick)
        m = sched.metrics()
        return in_flight_max[0], {k: m[k] for k in
                                  ("completed", "generated_tokens",
                                   "decode_steps", "prefill_dispatches",
                                   "sched_steps")}

    (peak1, m1), (peak2, m2) = run(), run()
    assert m1 == m2
    assert m1["completed"] == 6
    assert peak1 == peak2 == 2          # population pinned at concurrency


def test_preemption_resume_rides_the_prefix_cache():
    """With the prefix cache on, the preempted request's recompute
    resume adopts its own boundary snapshot instead of re-dispatching
    the whole prompt-so-far — counted in ``recompute_tokens_saved`` —
    and the final stream still equals an uninterrupted run."""
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]   # 8 tokens = one cache chunk
    n_new = 6

    clock = StepClock()
    ref = _sched(_engine(slots=1), clock, prefill_token_budget=None)
    r_ref = ref.submit(prompt, max_new_tokens=n_new, arrival=0.0)
    _drain(ref, clock)

    clock = StepClock()
    eng = _engine(slots=1, prefix_cache_bytes=1 << 24)
    sched = _sched(eng, clock, prefill_token_budget=None, preempt_age=2.0)
    r0 = sched.submit(prompt, max_new_tokens=n_new, arrival=0.0)
    sched.submit([2, 7, 1], max_new_tokens=2, arrival=0.0)
    _drain(sched, clock)

    assert r0.preemptions == 1
    assert r0.generated == r_ref.generated
    # the initial prefill stored the 8-token boundary; the resume
    # adopted it, so only the generated tokens were re-dispatched
    m = sched.metrics()
    assert m["recompute_tokens_saved"] == len(prompt)
    assert m["prefill_tokens_saved"] == len(prompt)
    assert m["prefix_hits"] == 1
    # cache-less metrics() stays cache-free (keys are gated on wiring)
    assert "prefix_hits" not in ref.metrics()
    assert ref.metrics()["recompute_tokens_saved"] == 0


def test_shared_prefix_traffic_seeded_and_shares_prefixes():
    kw = dict(seed=7, vocab_size=100, n_prefixes=3, prefix_len=8,
              user_len=(2, 5), out_len=(2, 4))
    a = synth_shared_prefix_traffic(12, 0.5, **kw)
    b = synth_shared_prefix_traffic(12, 0.5, **kw)
    assert [t.prompt for t in a] == [t.prompt for t in b]
    assert [t.arrival for t in a] == [t.arrival for t in b]
    heads = [tuple(t.prompt[:8]) for t in a]
    assert len(set(heads)) <= 3         # drawn from the fixed pool
    assert max(heads.count(h) for h in set(heads)) >= 2   # actual sharing
    # rate scales arrivals only, exactly like synth_traffic
    c = synth_shared_prefix_traffic(12, 1.0, **kw)
    np.testing.assert_allclose([t.arrival for t in c],
                               [t.arrival / 2 for t in a])
    assert [t.prompt for t in c] == [t.prompt for t in a]


def test_open_loop_run_is_deterministic():
    """Two fresh open-loop runs over the same seeded traffic produce
    identical scheduling metrics (the property the bench's exact CI
    gates rely on)."""
    arch, _ = _arch_params()
    traffic = synth_traffic(6, 0.3, seed=1, vocab_size=arch.vocab_size,
                            prompt_len=(3, 12), out_len=(2, 5))

    def run():
        clock = StepClock()
        eng = _engine(slots=2)
        sched = _sched(eng, clock, prefill_token_budget=6)
        run_open_loop(sched, traffic, tick=clock.tick)
        m = sched.metrics(slo_ttft=30.0)
        return {k: m[k] for k in
                ("completed", "completed_in_slo", "sched_steps",
                 "decode_steps", "prefill_dispatches", "queue_depth_max",
                 "generated_tokens", "goodput_tokens")}

    m1, m2 = run(), run()
    assert m1 == m2
    assert m1["completed"] == 6
    assert m1["decode_steps"] > 0 and m1["prefill_dispatches"] > 0
