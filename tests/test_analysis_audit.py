"""Jaxpr ledger audit (``repro.analysis.jaxpr_audit``): real archs audit
clean (every MAC tagged or declared digital, per-contract counts matching
the CostLedger exactly), and one seeded untagged contraction in a model
layer fails the audit with the leak's source location — the property the
whole pass exists to enforce."""
import jax.numpy as jnp

from repro.analysis.jaxpr_audit import MARKER_RE, audit_arch, audit_phase
from repro.configs import get_config
from repro.kernels.ops import site_marker
from repro.models import layers as L


def test_marker_grammar_roundtrip():
    m = MARKER_RE.fullmatch(site_marker("attn_qkv", 4, 896, 1152))
    assert m is not None
    assert m.group("site") == "attn_qkv"
    assert tuple(map(int, (m.group("m"), m.group("k"), m.group("n")))) == \
        (4, 896, 1152)
    # underscored site names must parse whole: the regex anchors the site
    # on the "_m<digits>_k<digits>_n<digits>" suffix, which no site name
    # can contain
    m = MARKER_RE.fullmatch(site_marker("moe_expert", 8, 64, 128))
    assert m is not None and m.group("site") == "moe_expert"


def test_paper_arch_decode_audits_clean():
    arch = get_config("paper-cim-120m").reduced()
    res = audit_phase(arch, "decode")
    assert res["untagged"] == 0, res["untagged_details"]
    assert res["ledger_mismatches"] == 0, res["ledger_mismatch_details"]
    assert res["tagged_values"] > 0
    # the cross-check really binds: every ledger contract was traced
    # exactly as many times as it was recorded
    assert res["contracts"]
    for key, c in res["contracts"].items():
        assert c["ledger"] == c["traced"], (key, c)


def test_train_grad_audits_clean_with_transposes_excluded():
    arch = get_config("qwen2-1.5b").reduced()
    res = audit_arch(arch, ("train",), bf16_regime_check=False)
    ph = res["phases"]["train"]
    assert res["failures"] == 0, ph
    assert ph["transposes"] > 0         # grad transposes seen, not counted
    assert ph["declared_digital"] > 0   # attention scores + STE backward


def test_seeded_untagged_einsum_fails_audit_with_source(monkeypatch):
    """The acceptance criterion: an untagged contraction smuggled into a
    model layer must fail the audit and name this file as the source."""
    arch = get_config("paper-cim-120m").reduced()
    orig = L.rmsnorm

    def leaky_rmsnorm(p, x, eps=1e-6):
        out = orig(p, x, eps)
        return out @ jnp.eye(out.shape[-1], dtype=out.dtype)  # the leak

    monkeypatch.setattr(L, "rmsnorm", leaky_rmsnorm)
    res = audit_phase(arch, "decode")
    assert res["untagged"] > 0
    leak = res["untagged_details"][0]
    assert leak["primitive"] == "dot_general"
    assert leak["file"] and leak["file"].endswith("test_analysis_audit.py")
    assert isinstance(leak["line"], int) and leak["line"] > 0
    # the leak bypasses cim_matmul, so the ledger cross-check itself stays
    # clean — untagged and mismatch are independent failure axes
    assert res["ledger_mismatches"] == 0, res["ledger_mismatch_details"]
