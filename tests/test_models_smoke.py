"""Per-architecture smoke tests: reduced config, one forward + train-grad +
decode step on CPU; asserts shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_configs
from repro.models import decode_step, forward, init_cache, init_params, train_loss

ARCHS = [
    "arctic-480b", "grok-1-314b", "qwen2-1.5b", "gemma3-1b", "granite-8b",
    "stablelm-3b", "mamba2-1.3b", "recurrentgemma-9b", "musicgen-medium",
    "chameleon-34b",
]


def _batch(cfg, key, b=2, s=32):
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_grad(name):
    cfg = get_config(name).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, aux, _ = forward(params, batch["inputs"], cfg)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), name
    (total, metrics), grads = jax.value_and_grad(train_loss, has_aux=True)(
        params, batch, cfg)
    assert bool(jnp.isfinite(total))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), name


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step(name):
    cfg = get_config(name).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    b, ctx = 2, 128
    cache = init_cache(cfg, b, ctx, dtype=jnp.float32)
    if cfg.input_mode == "tokens":
        tok = jnp.ones((b, 1), jnp.int32)
    else:
        tok = jax.random.normal(key, (b, 1, cfg.d_model), jnp.float32)
    logits, new_cache = decode_step(params, tok, cfg, cache, jnp.int32(5))
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), name
    # cache must change somewhere
    changed = jax.tree.map(
        lambda a, b_: bool(jnp.any(a != b_)), cache, new_cache)
    assert any(jax.tree.leaves(changed)), name


def test_all_ten_registered():
    names = set(list_configs())
    assert set(ARCHS) <= names
    assert "paper-cim-120m" in names


def test_cim_modes_in_model():
    """The paper's technique is a first-class switch on any arch."""
    cfg = get_config("qwen2-1.5b").reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    outs = {}
    for mode in ["off", "fakequant", "grmac"]:
        c = cfg.replace(cim=cfg.cim.with_mode(mode))
        logits, _, _ = forward(params, batch["inputs"], c)
        assert bool(jnp.all(jnp.isfinite(logits))), mode
        outs[mode] = logits
    # numerics differ between modes but stay correlated
    assert float(jnp.max(jnp.abs(outs["off"] - outs["fakequant"]))) > 0
    co = jnp.corrcoef(outs["off"].ravel(), outs["grmac"].ravel())[0, 1]
    assert float(co) > 0.8


def test_decode_matches_prefill_gemma3():
    """Ring-buffer local attention: decoding token-by-token matches the
    train-path logits of the same prefix (gemma3 has both local+global)."""
    cfg = get_config("gemma3-1b").reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    b, s = 1, 12
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full_logits, _, _ = forward(params, toks, cfg)
    cache = init_cache(cfg, b, 128, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = decode_step(
            params, toks[:, t:t+1], cfg, cache, jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    assert jnp.allclose(dec, full_logits, atol=2e-2), float(
        jnp.max(jnp.abs(dec - full_logits)))
