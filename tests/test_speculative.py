"""Speculative-decode correctness net: greedy draft/verify/accept must be
bit-identical to sequential decode on every cache family (the recurrent
snapshot->verify->restore rollback is the part that can silently rot),
speculation must compose with max_tokens caps, EOS mid-chunk, preemption
recompute-resume, and prefix-cache adoption, and the sampled acceptance
rule must keep seeded lanes reproducible and temperature-0 lanes exact."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig
from repro.serving.params import SamplingParams
from repro.serving.scheduler import (Scheduler, SchedulerConfig, StepClock,
                                     run_open_loop,
                                     synth_shared_prefix_traffic,
                                     synth_traffic)
from repro.serving.speculative import (SpecConfig, SpecDecoder,
                                       draft_arch_for, price_speculation)

ARCHS = [
    ("attn", "qwen2-1.5b"),
    ("rglru", "recurrentgemma-9b"),   # rglru + local ring layers
    ("ssm", "mamba2-1.3b"),
    ("moe", "grok-1-314b"),
]


def _setup(name, seed=0):
    arch = get_config(name).reduced()
    params = init_params(jax.random.PRNGKey(seed), arch)
    return arch, params


def _seq_tokens(arch, params, prompt, n, **req_kw):
    eng = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    slot = eng.add_request(prompt, params=SamplingParams(**req_kw))
    while eng.active[slot] and len(eng.tokens[slot]) - len(prompt) < n:
        eng.step()
    return eng.tokens[slot][len(prompt):][:n], eng.finish_reason(slot)


def _spec_tokens(arch, params, prompt, n, spec_cfg, draft_fn=None,
                 max_steps=64, **req_kw):
    eng = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    dec = SpecDecoder(eng, spec_cfg, draft_fn=draft_fn)
    slot = eng.add_request(prompt, params=SamplingParams(**req_kw))
    toks = list(eng.tokens[slot][len(prompt):])   # prefill-sampled first
    for _ in range(max_steps):
        if len(toks) >= n or not eng.active[slot]:
            break
        r = dec.step()
        for o in r.outputs:
            if o.slot == slot:
                toks.extend(o.tokens)
    return toks[:n], eng, eng.finish_reason(slot)


@pytest.mark.parametrize("label,name", ARCHS)
def test_greedy_spec_bitwise_digital_draft(label, name):
    """Greedy speculative output == sequential decode, bit for bit, with
    the digital (CIM-off numerics) drafter on every cache family."""
    arch, params = _setup(name)
    prompt = [int(t) for t in
              np.random.RandomState(0).randint(1, arch.vocab_size, 7)]
    ref, _ = _seq_tokens(arch, params, prompt, 12)
    got, eng, _ = _spec_tokens(arch, params, prompt, 12,
                               SpecConfig(k=4, draft="digital"))
    assert got == ref, (label, got, ref)
    assert eng.stats["spec_steps"] > 0
    assert eng.stats["verify_dispatches"] == eng.stats["spec_steps"]


@pytest.mark.parametrize("label,name", [("rglru", "recurrentgemma-9b"),
                                        ("ssm", "mamba2-1.3b")])
def test_forced_rejection_rollback(label, name):
    """An adversarial drafter that is ALWAYS wrong forces a rollback +
    repair on every step — the recurrent/ring state must come back
    exactly, so the stream still equals sequential decode."""
    arch, params = _setup(name)
    prompt = [int(t) for t in
              np.random.RandomState(1).randint(1, arch.vocab_size, 6)]
    ref, _ = _seq_tokens(arch, params, prompt, 10)

    def bad_draft(cur, t):
        # drafting the sequential token + 1 mod vocab is always a mismatch
        ref_next = np.asarray(ref, np.int64)
        return ((cur + 1) % arch.vocab_size).astype(np.int32)

    got, eng, _ = _spec_tokens(arch, params, prompt, 10,
                               SpecConfig(k=4, draft="self"),
                               draft_fn=bad_draft)
    assert got == ref, (label, got, ref)
    # every accepted count is exactly 1 (correction token only), and every
    # live partial acceptance repaired the recurrent state
    assert eng.stats["spec_tokens"] == eng.stats["spec_steps"]
    assert eng.stats["repair_dispatches"] > 0
    assert eng.stats["draft_dispatches"] == 0   # seam bypasses dispatches


def test_self_draft_full_acceptance():
    """The self drafter runs the target's own decode executable, so greedy
    acceptance is structurally total: k tokens per step, zero repairs."""
    arch, params = _setup("qwen2-1.5b")
    prompt = [3, 1, 4, 1, 5]
    ref, _ = _seq_tokens(arch, params, prompt, 12)
    got, eng, _ = _spec_tokens(arch, params, prompt, 12,
                               SpecConfig(k=4, draft="self"))
    assert got == ref
    assert eng.stats["spec_tokens"] == 4 * eng.stats["spec_steps"]
    assert eng.stats["repair_dispatches"] == 0
    assert eng.stats["draft_dispatches"] == 3 * eng.stats["spec_steps"]


def test_spec_eos_mid_chunk():
    """An EOS accepted in the middle of a verify chunk truncates the
    emission there and frees the lane with reason "eos"."""
    arch, params = _setup("qwen2-1.5b")
    prompt = [5, 6, 7, 8]
    ref, _ = _seq_tokens(arch, params, prompt, 8)
    eos = ref[5]           # sequential emits this mid-way through a chunk
    ref_eos, reason = _seq_tokens(arch, params, prompt, 8, eos_id=eos)
    assert reason == "eos"
    got, eng, sreason = _spec_tokens(arch, params, prompt, 8,
                                     SpecConfig(k=4, draft="self"),
                                     eos_id=eos)
    assert got == ref_eos
    assert sreason == "eos"
    assert got[-1] == eos


def test_spec_max_tokens_cap_frees_slot():
    """A request capped at max_tokens emits exactly that many under
    speculation (a chunk never overshoots the cap), finishes "length",
    and its slot is immediately reclaimable."""
    arch, params = _setup("mamba2-1.3b")
    prompt = [2, 7, 1, 8]
    ref, _ = _seq_tokens(arch, params, prompt, 10)
    got, eng, reason = _spec_tokens(arch, params, prompt, 10,
                                    SpecConfig(k=4, draft="self"),
                                    max_tokens=5)
    assert got == ref[:5]
    assert reason == "length"
    assert eng.free_slots() == eng.cfg.batch_slots
    s2 = eng.add_request([9, 9, 2])       # slot reuse after a spec finish
    r = SpecDecoder(eng, SpecConfig(k=3, draft="self")).step()
    assert any(o.slot == s2 and o.tokens for o in r.outputs)


def test_spec_k_per_request_override():
    """SamplingParams.spec_k overrides the decoder default per lane:
    spec_k=1 opts out (one token per step), spec_k=3 drafts 2."""
    arch, params = _setup("qwen2-1.5b")
    eng = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    dec = SpecDecoder(eng, SpecConfig(k=4, draft="self"))
    s0 = eng.add_request([1, 2, 3], params=SamplingParams(spec_k=1))
    s1 = eng.add_request([4, 5, 6], params=SamplingParams(spec_k=3))
    r = dec.step()
    per_slot = {o.slot: len(o.tokens) for o in r.outputs}
    assert per_slot[s0] == 1
    assert per_slot[s1] == 3


def test_spec_preemption_resume():
    """Speculation composes with recompute preemption: greedy streams are
    preemption-invariant, so an overloaded spec run must emit the same
    tokens as an uncontended sequential run."""
    arch, params = _setup("recurrentgemma-9b")
    traffic = synth_traffic(6, 2.0, seed=5, vocab_size=arch.vocab_size,
                            prompt_len=(4, 10), out_len=(6, 10))

    def run(spec, slots, preempt_age):
        eng = Engine(arch, params, ServeConfig(batch_slots=slots,
                                               max_ctx=64))
        clk = StepClock()
        sched = Scheduler(eng, SchedulerConfig(preempt_age=preempt_age),
                          clock=clk.now, spec=spec)
        run_open_loop(sched, traffic, tick=clk.tick)
        return ({r.rid: list(r.generated) for r in sched.finished},
                sched.stats["preempted"])

    ref, _ = run(None, slots=4, preempt_age=None)
    got, preempted = run(SpecConfig(k=4, draft="self"), slots=1,
                         preempt_age=1.0)
    assert preempted > 0          # the drill actually preempted
    assert got == ref


def test_spec_prefix_cache_adoption():
    """Speculation composes with prefix-cache adoption: shared-prefix
    traffic served spec + cache emits the same streams as sequential
    cache-off, while actually hitting the cache."""
    arch, params = _setup("qwen2-1.5b")
    traffic = synth_shared_prefix_traffic(
        6, 1.0, seed=2, vocab_size=arch.vocab_size, n_prefixes=2,
        prefix_len=16, user_len=(2, 6), out_len=(4, 8))

    def run(spec, cache_bytes):
        eng = Engine(arch, params,
                     ServeConfig(batch_slots=2, max_ctx=64,
                                 prefix_cache_bytes=cache_bytes))
        clk = StepClock()
        # budget 8 slices prefill at cache-chunk boundaries, so the
        # shared prefixes actually get inserted (single-chunk prefills
        # never cross an interior boundary)
        sched = Scheduler(eng, SchedulerConfig(prefill_token_budget=8),
                          clock=clk.now, spec=spec)
        run_open_loop(sched, traffic, tick=clk.tick)
        return ({r.rid: list(r.generated) for r in sched.finished},
                eng.stats["prefix_hit_tokens"])

    ref, _ = run(None, None)
    got, hit = run(SpecConfig(k=4, draft="self"), 64 << 20)
    assert hit > 0
    assert got == ref


def test_sampled_spec_seeded_and_mixed():
    """Sampled acceptance: a seeded lane's stream is reproducible across
    runs and differs across seeds; a temperature-0 lane in the same batch
    gets exact greedy acceptance inside the sampled verify."""
    arch, params = _setup("recurrentgemma-9b")

    def run(seed):
        eng = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
        dec = SpecDecoder(eng, SpecConfig(k=4, draft="self"))
        s0 = eng.add_request([3, 1, 4], params=SamplingParams(
            temperature=0.9, seed=seed))
        s1 = eng.add_request([2, 7, 1], params=SamplingParams(
            temperature=0.0))
        t0 = list(eng.tokens[s0][3:])    # prefill-sampled first tokens
        t1 = list(eng.tokens[s1][3:])
        for i in range(6):
            r = dec.step(jax.random.PRNGKey(i))
            for o in r.outputs:
                (t0 if o.slot == s0 else t1).extend(o.tokens)
        return t0[:6], t1[:8]

    a0, a1 = run(42)
    b0, b1 = run(42)
    c0, _ = run(7)
    ref, _ = _seq_tokens(arch, params, [2, 7, 1], 8)
    assert a0 == b0
    assert a0 != c0
    assert a1 == ref
    assert all(0 <= t < arch.vocab_size for t in a0 + c0)


def test_draft_arch_resolution():
    arch = get_config("qwen2-1.5b").reduced()
    cim_on = arch.replace(cim=arch.cim.with_mode("grmac"))
    assert draft_arch_for(cim_on, "self") is cim_on
    dig = draft_arch_for(cim_on, "digital")
    assert not dig.cim.enabled
    other = get_config("mamba2-1.3b").reduced()
    with pytest.raises(ValueError):
        draft_arch_for(cim_on, other)     # different model: no shared cache
    with pytest.raises(ValueError):
        draft_arch_for(cim_on, "turbo")
    with pytest.raises(ValueError):
        SpecConfig(k=1)


def test_price_speculation_verdict():
    """The energy account prices measured counters deterministically: a
    digital drafter with high acceptance must beat sequential grmac
    decode; the disabled-CIM case reports enabled=False."""
    arch = get_config("qwen2-1.5b").reduced()
    cim = arch.replace(cim=arch.cim.with_mode("grmac"))
    stats = {"draft_dispatches": 30, "verify_dispatches": 10,
             "repair_dispatches": 0, "spec_steps": 10, "spec_tokens": 40}
    rep = price_speculation(cim, draft_arch_for(cim, "digital"), stats, 4,
                            n_cols=1 << 8)
    assert rep["enabled"]
    assert rep["accepted_tokens_per_step"] == 4.0
    rep2 = price_speculation(cim, draft_arch_for(cim, "digital"), stats, 4,
                             n_cols=1 << 8)
    assert rep == rep2                     # deterministic pricing
    assert price_speculation(arch, arch, stats, 4) == {"enabled": False}
