"""Prefix cache (``repro.serving.prefix_cache``): trie/LRU unit
behavior on synthetic snapshots, engine-level exactness — a prefix-hit
token stream must be bit-identical to a cold prefill across all four
cache families — attention-only subsumption vs recurrent exact-boundary
hits, dispatch/savings accounting, and the compile/transfer invariants
under a hit-heavy trace.

Everything is greedy and seeded, so streams and counters are exact."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig
from repro.serving.prefix_cache import PrefixCache, snapshot_slot

# the four cache families the engine serves — the exactness contract
# (warm stream == cold stream) must hold on every one
FAMILIES = [
    ("attn", "qwen2-1.5b"),
    ("rglru", "recurrentgemma-9b"),
    ("ssm", "mamba2-1.3b"),
    ("moe", "grok-1-314b"),
]

_CACHE = {}


def _arch_params(name="qwen2-1.5b"):
    if name not in _CACHE:
        arch = get_config(name).reduced()
        _CACHE[name] = (arch, init_params(jax.random.PRNGKey(0), arch))
    return _CACHE[name]


def _engine(name="qwen2-1.5b", slots=2, ctx=64, **cfg_kw):
    arch, params = _arch_params(name)
    return Engine(arch, params,
                  ServeConfig(batch_slots=slots, max_ctx=ctx, **cfg_kw))


def _serve(eng, prompt, n_new, chunk=8):
    """Chunked (scheduler-style) prefill + greedy decode of one request;
    returns (generated stream, tokens adopted from the cache). Driving
    prefill in cache-chunk-sized steps lands a snapshot boundary per
    chunk — the production (budgeted-scheduler) dispatch pattern."""
    slot = eng.begin_request(prompt)
    adopted = eng.adopted_prefix(slot)
    while eng.prefill_remaining(slot):
        eng.advance_prefill(slot, max_tokens=chunk)
    eng.finish_prefill(slot)
    for _ in range(n_new - 1):          # first token came from prefill
        eng.step()
    out = eng.tokens[slot][len(prompt):len(prompt) + n_new]
    eng.release_slot(slot)
    return out, adopted


# ------------------------------------------------------------ unit: trie
def _fake_snap(nbytes, kind="ssm"):
    """Synthetic snapshot pytree with a known byte size. ``kind`` picks
    the layer-family suffix (non-attn kinds disable sliced lookups, so
    LRU tests see only exact-boundary hits)."""
    return {"tail": {f"l0_{kind}": {"h": np.zeros(nbytes, np.uint8)}}}


def test_insert_requires_chunk_multiple():
    pc = PrefixCache(1 << 20, chunk_tokens=4)
    with pytest.raises(ValueError, match="multiple"):
        pc.insert([1, 2, 3, 4, 5, 6], lambda: _fake_snap(16))
    with pytest.raises(ValueError, match="multiple"):
        pc.insert([], lambda: _fake_snap(16))


def test_lookup_leaves_at_least_one_suffix_token():
    """A prompt equal to a stored prefix must NOT fully adopt it:
    ``finish_prefill`` needs real last-token logits, so lookup caps at
    ``len(prompt) - 1`` whole chunks."""
    pc = PrefixCache(1 << 20, chunk_tokens=4)
    pc.insert([1, 2, 3, 4], lambda: _fake_snap(16))
    assert pc.lookup([1, 2, 3, 4]) is None          # would adopt all 4
    hit = pc.lookup([1, 2, 3, 4, 9])                # 1 suffix token left
    assert hit is not None and hit[0] == 4
    # shorter than one chunk + 1: nothing adoptable
    assert pc.lookup([1, 2, 3]) is None
    assert pc.stats == {"hits": 1, "misses": 2, "inserts": 1,
                        "evictions": 0, "hit_tokens": 4, "bytes": 16}


def test_partial_chunk_prefix_matches_only_whole_chunks():
    """Lookup adopts whole stored chunks only: a prompt diverging inside
    the second chunk still hits the first-chunk boundary."""
    pc = PrefixCache(1 << 20, chunk_tokens=4)
    pc.insert([1, 2, 3, 4], lambda: _fake_snap(16))
    pc.insert([1, 2, 3, 4, 5, 6, 7, 8], lambda: _fake_snap(32))
    hit = pc.lookup([1, 2, 3, 4, 5, 6, 99, 98, 97])  # diverges at token 7
    assert hit is not None and hit[0] == 4
    hit = pc.lookup([1, 2, 3, 4, 5, 6, 7, 8, 9])     # full second chunk
    assert hit is not None and hit[0] == 8


def test_insert_dedupes_without_building_snapshot():
    """Re-inserting a stored boundary must not call the snapshot thunk
    (identical prefix ⇒ identical state, by determinism)."""
    pc = PrefixCache(1 << 20, chunk_tokens=4)
    assert pc.insert([1, 2, 3, 4], lambda: _fake_snap(16)) is True
    def boom():
        raise AssertionError("snapshot rebuilt for a cached boundary")
    assert pc.insert([1, 2, 3, 4], boom) is False
    assert pc.stats["inserts"] == 1 and pc.bytes == 16


def test_lru_eviction_under_byte_budget():
    """Budget for two 128-byte entries: a lookup refreshes A's recency,
    so inserting C evicts B (the least recently used), and the evicted
    boundary misses afterward."""
    pc = PrefixCache(256, chunk_tokens=4)
    a, b, c = [1, 2, 3, 4], [5, 6, 7, 8], [9, 10, 11, 12]
    pc.insert(a, lambda: _fake_snap(128))
    pc.insert(b, lambda: _fake_snap(128))
    assert pc.bytes == 256 and len(pc) == 2
    assert pc.lookup(a + [0]) is not None        # A is now MRU
    pc.insert(c, lambda: _fake_snap(128))        # over budget -> evict B
    assert pc.stats["evictions"] == 1
    assert pc.bytes == 256 and len(pc) == 2
    assert pc.lookup(b + [0]) is None            # B gone
    assert pc.lookup(a + [0]) is not None
    assert pc.lookup(c + [0]) is not None
    # the evicted path was pruned from the trie, not left dangling
    assert tuple(b) not in pc._root.children


def test_oversize_snapshot_refused_and_path_pruned():
    pc = PrefixCache(64, chunk_tokens=4)
    assert pc.insert([1, 2, 3, 4], lambda: _fake_snap(128)) is False
    assert pc.stats["inserts"] == 0 and pc.bytes == 0 and len(pc) == 0
    assert not pc._root.children                 # no dangling path nodes


# ------------------------------------------- engine: exactness contract
@pytest.mark.parametrize("family,name", FAMILIES)
def test_prefix_hit_stream_bit_identical_to_cold(family, name):
    """Two prompts sharing a 16-token (2-chunk) prefix: the second
    adopts the cached boundary, prefills only its suffix, and its full
    greedy stream must equal a cache-less cold engine's bit-for-bit."""
    shared = list(range(1, 17))
    p1 = shared + [21, 22, 23, 24, 25]
    p2 = shared + [31, 32, 33]
    n_new = 4

    cold = _engine(name)
    ref1, _ = _serve(cold, p1, n_new)
    ref2, _ = _serve(cold, p2, n_new)

    warm = _engine(name, prefix_cache_bytes=1 << 24)
    out1, adopted1 = _serve(warm, p1, n_new)
    out2, adopted2 = _serve(warm, p2, n_new)

    assert adopted1 == 0 and out1 == ref1        # cold miss, stores 8/16
    assert adopted2 == 16, f"{family}: expected a 16-token adoption"
    assert out2 == ref2, f"{family}: hit stream diverged from cold"
    pc = warm.prefix_cache
    assert pc.stats["hits"] == 1 and pc.stats["misses"] == 1
    assert pc.stats["hit_tokens"] == 16
    # dispatch accounting: warm prefilled p1 whole + p2's suffix only
    assert warm.stats["prefill_tokens"] == len(p1) + len(p2) - 16
    assert warm.stats["prefix_hit_tokens"] == 16
    assert cold.stats["prefill_tokens"] == len(p1) + len(p2)


def test_attn_subsumption_slices_longer_snapshot():
    """Pure-attention archs rewind: a single stored 24-token snapshot
    (the only boundary a blocking ``add_request`` lands) serves a prompt
    sharing just 16 tokens by slicing its KV rows — and the sliced-hit
    stream still matches a cold run exactly."""
    prefix24 = list(range(40, 64))
    p2 = prefix24[:16] + [7, 8, 9]
    cold = _engine("qwen2-1.5b")
    ref, _ = _serve(cold, p2, 4)

    warm = _engine("qwen2-1.5b", prefix_cache_bytes=1 << 24)
    # a blocking add_request dispatches the whole prompt as one chunk,
    # so the only boundary it can store is the prompt end itself
    warm.release_slot(warm.add_request(prefix24))
    assert warm.prefix_cache.stats["inserts"] == 1   # only the 24-end
    out, adopted = _serve(warm, p2, 4)
    assert adopted == 16                             # sliced, not exact
    assert out == ref
    assert warm.prefix_cache.stats["hit_tokens"] == 16


def test_recurrent_hits_only_stored_boundaries():
    """Recurrent state can't be rewound: with only a 24-token boundary
    stored, a 16-token shared prefix misses; sharing all 24 hits."""
    prefix24 = list(range(40, 64))
    eng = _engine("recurrentgemma-9b", prefix_cache_bytes=1 << 24)
    eng.release_slot(eng.add_request(prefix24))
    assert eng.prefix_cache.stats["inserts"] == 1

    _, adopted = _serve(eng, prefix24[:16] + [7, 8, 9], 2)
    assert adopted == 0                              # no 16-boundary
    _, adopted = _serve(eng, prefix24 + [7, 8, 9], 2)
    assert adopted == 24                             # exact boundary


def test_chunked_prefill_stores_every_boundary():
    """Scheduler-style chunked driving lands a snapshot per cache chunk
    (the dense-boundary production path), so recurrent archs hit at any
    shared chunk multiple."""
    eng = _engine("mamba2-1.3b", prefix_cache_bytes=1 << 24)
    prompt = list(range(1, 25)) + [90, 91]           # 24 shared + suffix
    _serve(eng, prompt, 2)                           # 8/16/24 stored
    assert eng.prefix_cache.stats["inserts"] == 3
    _, adopted = _serve(eng, list(range(1, 9)) + [50, 51], 2)
    assert adopted == 8


# ------------------------------------------------- engine: wiring rules
def test_prefix_cache_requires_bucketed_mode():
    arch, params = _arch_params()
    with pytest.raises(ValueError, match="bucketed"):
        Engine(arch, params,
               ServeConfig(batch_slots=1, max_ctx=64, prefill_mode="token",
                           prefix_cache_bytes=1 << 20))


def test_prefix_cache_chunk_must_match_bucket_min():
    arch, params = _arch_params()
    with pytest.raises(ValueError, match="bucket"):
        Engine(arch, params, ServeConfig(batch_slots=1, max_ctx=64),
               prefix_cache=PrefixCache(1 << 20, chunk_tokens=4))


def test_snapshot_restore_roundtrip_is_device_side():
    """Snapshots never leave the device: every leaf of a live snapshot
    is a jax.Array, sized as the docstring promises (attn layers carry
    ``length`` context rows, recurrent layers their full tiny state)."""
    eng = _engine("qwen2-1.5b", prefix_cache_bytes=1 << 24)
    slot = eng.begin_request(list(range(1, 17)) + [3])
    while eng.prefill_remaining(slot):
        eng.advance_prefill(slot, max_tokens=8)
    snap = snapshot_slot(eng.cache, slot, 16)
    leaves = jax.tree.leaves(snap)
    assert leaves and all(isinstance(a, jax.Array) for a in leaves)


# --------------------------------------------- invariants: hit-heavy
def test_prefix_invariants_hold_under_hit_heavy_trace():
    """Compile budget (≤1 trace per executable) and the one-D2H-fetch
    rule re-proven with the cache adopting prefixes mid-trace."""
    from repro.analysis.invariants import run_prefix_invariants
    res = run_prefix_invariants(("qwen2-1.5b",))
    assert res["violations"] == 0, res
    rep = res["configs"]["qwen2-1.5b"]
    assert rep["prefix_hits"] >= 1
    assert rep["prefill_tokens_saved"] > 0
