"""Sharding / dry-run machinery on a small forced-host-device mesh.

NOTE: needs its own process for XLA_FLAGS, so it spawns subprocesses for
the device-count-sensitive parts; pure-logic tests run in-process.
"""
import subprocess
import sys

from repro.launch.roofline import (
    collective_bytes,
    model_flops_estimate,
)
from repro.launch.specs import SHAPES, cell_is_runnable
from repro.configs import get_config


def test_collective_parser():
    hlo = """
  %ag = bf16[2048,512]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %rs.1 = f32[128,64]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = (f32[16,16]{1,0}) collective-permute-start(%w)
  %a2a = bf16[64]{0} all-to-all(%v)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 2048 * 512 * 2
    assert got["all-reduce"] == 1024 * 4 * 2          # ring weight 2x
    assert got["reduce-scatter"] == 128 * 64 * 4
    assert got["collective-permute"] == 16 * 16 * 4
    assert got["all-to-all"] == 64 * 2


def test_model_flops_estimates():
    cfg = get_config("qwen2-1.5b")
    t4k = SHAPES["train_4k"]
    mf = model_flops_estimate(cfg, t4k)
    # qwen2-1.5b ~1.3B non-embedding params, 1M tokens, 6ND
    assert 5e15 < mf < 1.5e16, mf
    # MoE: active << total
    moe = get_config("arctic-480b")
    mf_moe = model_flops_estimate(moe, t4k)
    assert mf_moe < 6 * moe.param_count() * 1_048_576 * 0.2


def test_long500k_skips():
    for name in ["qwen2-1.5b", "granite-8b", "chameleon-34b"]:
        ok, reason = cell_is_runnable(get_config(name), "long_500k")
        assert not ok and "full-attention" in reason
    for name in ["gemma3-1b", "mamba2-1.3b", "recurrentgemma-9b"]:
        ok, _ = cell_is_runnable(get_config(name), "long_500k")
        assert ok


_SUBPROC_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.parallel.sharding import param_specs, use_mesh
from repro.models import init_params, train_loss
from repro.data.pipeline import DataConfig, SyntheticLM

from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
arch = get_config("qwen2-1.5b").reduced().replace(n_layers=2)
params = init_params(jax.random.PRNGKey(0), arch)
specs = param_specs(params, mesh)
# embed (512,128): both dims divisible -> sharded
flat = jax.tree_util.tree_flatten_with_path(specs)[0]
by_name = {"/".join(str(getattr(k, "key", k)) for k in p): s for p, s in flat}
assert by_name["embed"] == P("model", ("data",)), by_name["embed"]
# compute loss sharded vs unsharded -> numerics must agree
pipe = SyntheticLM(DataConfig(global_batch=4, seq_len=64,
                              vocab_size=arch.vocab_size))
batch = pipe.batch_at(0)
l_ref, _ = train_loss(params, batch, arch)
with use_mesh(mesh):
    from jax.sharding import NamedSharding
    ns = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda s: isinstance(s, P))
    p_sh = jax.device_put(params, ns)
    l_sh, _ = jax.jit(lambda pp, bb: train_loss(pp, bb, arch))(p_sh, batch)
np.testing.assert_allclose(float(l_ref), float(l_sh), rtol=2e-4)
print("OK", float(l_ref), float(l_sh))
"""


def test_sharded_loss_matches_unsharded():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC_SNIPPET],
        capture_output=True, text=True, env=None, cwd=".",
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_mesh_model_parallel_remap():
    """Logical mesh re-mapping (§Perf P2.2) preserves chip counts."""
    src = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.launch.mesh import make_production_mesh
import repro.launch.mesh as M
M.make_production_mesh.__defaults__  # noqa
# monkey: shrink pod for the 8-device test env
from repro.compat import make_mesh
def mk(multi_pod=False, model_parallel=4, chips=8):
    dp = chips // model_parallel
    shape = (dp, model_parallel)
    return make_mesh(shape, ("data", "model"))
m1 = mk(model_parallel=4)
m2 = mk(model_parallel=1)
assert m1.size == m2.size == 8
assert m2.shape["model"] == 1
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", src], capture_output=True,
                       text=True)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-1500:]


def test_padded_vocab_values():
    assert get_config("mamba2-1.3b").padded_vocab == 50432
    assert get_config("qwen2-1.5b").padded_vocab == 152064
    assert get_config("stablelm-3b").padded_vocab == 50432
    assert get_config("arctic-480b").padded_vocab == 32000  # already aligned
