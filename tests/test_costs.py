"""Cost/trace subsystem regression net.

1. The retired analytic MAC census (the hand-derived per-arch formula that
   ``energy_report`` used before the CostLedger) is kept HERE as the
   oracle: the ledger built by a shape-only trace of the real decode step
   must reproduce its per-token op counts with exact integer equality for
   every registered arch config. If a model change moves the counts, this
   test localizes whether the accounting followed (update the oracle
   consciously) or broke.
2. ``CIMConfig.site_overrides`` set to the base design must be
   bit-identical to no overrides (policy resolution cannot perturb
   numerics), and "off"/design overrides must act per site.
3. Phase reports price analog sites only; a site forced off keeps its ops
   in the ledger but out of the pJ figure.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.core import costs
from repro.core.cim_config import CIMConfig, SiteDesign
from repro.models import forward, init_params


# --------------------------------------------------------------- census
def analytic_census_decode_macs(arch) -> int:
    """The retired hand-rolled MAC census (verbatim from the old
    ``serving.engine.energy_report``): projection MACs per decoded token,
    re-deriving every architecture's structure by hand."""
    macs = 0
    d = arch.d_model
    for kind in arch.blocks():
        if kind in ("attn", "local"):
            macs += d * (arch.n_heads + 2 * arch.n_kv_heads) * arch.d_head
            macs += arch.n_heads * arch.d_head * d
            ffn = True
        elif kind == "rglru":
            w = arch.rnn_width
            macs += 3 * d * w + w * d
            ffn = True
        elif kind == "ssm":
            macs += d * (2 * arch.d_inner + 2 * arch.ssm_state
                         + arch.ssm_heads) + arch.d_inner * d
            ffn = False
        if ffn and kind != "ssm":
            if arch.is_moe:
                f = arch.expert_d_ff
                nmat = 3 if arch.gated_mlp else 2
                macs += arch.top_k * nmat * d * f + d * arch.n_experts
                if arch.moe_dense_residual:
                    macs += nmat * d * arch.d_ff
            else:
                nmat = 3 if arch.gated_mlp else 2
                macs += nmat * d * arch.d_ff
    macs += d * arch.vocab_size  # LM head
    return macs


@pytest.mark.parametrize("name", list_configs())
def test_ledger_decode_matches_analytic_census(name):
    """Trace-derived decode op-counts == the retired census, exactly, for
    every registered arch (the ten assigned + the paper's edge config)."""
    arch = get_config(name)
    ledger = costs.trace_decode(arch)
    assert ledger.macs() == analytic_census_decode_macs(arch), name


def test_ledger_scales_with_batch_and_all_sites_labeled():
    arch = get_config("grok-1-314b")
    one = costs.trace_decode(arch, batch=1)
    four = costs.trace_decode(arch, batch=4)
    assert four.macs() == 4 * one.macs()
    # every contract carries a canonical site label (nothing "unsited")
    assert "unsited" not in one.sites()
    assert {"attn_qkv", "attn_o", "moe_router", "moe_expert", "head"} \
        <= set(one.sites())


def test_prefill_and_train_traces_are_per_token_consistent():
    """Per-token structure is phase-invariant for a dense arch: one
    prefill bucket and one train step count bucket/seq × the decode
    step's MACs (the phases differ in M per contract, not in structure)."""
    arch = get_config("qwen2-1.5b")
    per_tok = costs.trace_decode(arch).macs()
    assert costs.trace_prefill(arch, bucket=32).macs() == 32 * per_tok
    assert costs.trace_train(arch, seq_len=64).macs() == 64 * per_tok


# ------------------------------------------------------- site overrides
def _tiny(mode="grmac"):
    arch = get_config("paper-cim-120m").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=256, vocab_size=512)
    return arch.replace(cim=arch.cim.with_mode(mode))


def test_site_overrides_identical_to_base_is_bit_identical():
    """Overriding every site with the base design's own values must be a
    no-op down to the last ulp of the logits (policy resolution cannot
    perturb numerics)."""
    arch = _tiny()
    base = arch.cim
    same = base
    for site in ("attn_qkv", "attn_o", "mlp", "head"):
        same = same.override_site(site, SiteDesign(
            mode=base.mode, granularity=base.granularity,
            fmt_x=base.fmt_x, fmt_w=base.fmt_w, n_r=base.n_r))
    arch_ov = arch.replace(cim=same)
    params = init_params(jax.random.PRNGKey(0), arch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              arch.vocab_size)
    a, _, _ = forward(params, toks, arch)
    b, _, _ = forward(params, toks, arch_ov)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_site_override_off_matches_apply_to_removal():
    """site_overrides=("head", "off") must equal the legacy coarse switch
    (apply_to without "head") bitwise — apply_to is the degenerate case."""
    arch = _tiny()
    via_override = arch.replace(cim=arch.cim.override_site("head", "off"))
    via_family = arch.replace(cim=dataclasses.replace(
        arch.cim, apply_to=("ffn", "qkvo", "expert")))
    params = init_params(jax.random.PRNGKey(0), arch)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                              arch.vocab_size)
    a, _, _ = forward(params, toks, via_override)
    b, _, _ = forward(params, toks, via_family)
    c, _, _ = forward(params, toks, arch)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.any(np.asarray(a) != np.asarray(c))  # the head really moved


def test_mixed_deployment_changes_numerics_per_site():
    """A conv-granularity head next to the gr-row body is first-class:
    it changes the logits vs the all-row deployment, and the resolved
    per-site configs report the right designs."""
    arch = _tiny()
    mixed_cim = arch.cim.override_site("head", SiteDesign(
        granularity="conv"))
    assert mixed_cim.for_site("head").granularity == "conv"
    assert mixed_cim.for_site("mlp").granularity == "row"
    mixed = arch.replace(cim=mixed_cim)
    params = init_params(jax.random.PRNGKey(0), arch)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                              arch.vocab_size)
    a, _, _ = forward(params, toks, arch)
    b, _, _ = forward(params, toks, mixed)
    assert np.any(np.asarray(a) != np.asarray(b))
    assert np.all(np.isfinite(np.asarray(b)))


def test_for_site_resolution_rules():
    cim = CIMConfig(mode="grmac")
    assert cim.for_site("attn_qkv") == dataclasses.replace(cim)
    assert cim.for_site("head").enabled
    # family not in apply_to -> off
    narrow = dataclasses.replace(cim, apply_to=("ffn",))
    assert not narrow.for_site("attn_qkv").enabled
    assert narrow.for_site("mlp").enabled
    # override wins over apply_to in both directions
    on = narrow.override_site("attn_qkv", SiteDesign(granularity="unit"))
    eff = on.for_site("attn_qkv")
    assert eff.enabled and eff.granularity == "unit"
    off = cim.override_site("mlp", "off")
    assert not off.for_site("mlp").enabled
    # a base-mode-off config with one analog override is "enabled"
    lone = CIMConfig(mode="off").override_site(
        "head", SiteDesign(mode="grmac"))
    assert lone.enabled and lone.for_site("head").enabled
    assert not lone.for_site("mlp").enabled


# ------------------------------------------------------------- pricing
def test_priced_report_skips_digital_sites():
    arch = _tiny()
    off_head = arch.replace(cim=arch.cim.override_site("head", "off"))
    full = costs.price_ledger(costs.trace_decode(arch), 1, n_cols=1 << 7)
    part = costs.price_ledger(costs.trace_decode(off_head), 1,
                              n_cols=1 << 7)
    # same structural ops, fewer analog ops, strictly less energy
    assert part["ops_per_token"] == full["ops_per_token"]
    assert part["analog_ops_per_token"] < full["analog_ops_per_token"]
    assert part["pj_per_token"] < full["pj_per_token"]
    assert part["sites"]["head"]["mode"] == "off"
    assert part["sites"]["head"]["pj_per_token"] == 0.0


def test_explore_sites_sweeps_the_ledger():
    from repro.core.dse import explore_sites
    arch = _tiny()
    ledger = costs.trace_decode(arch)
    res = explore_sites(arch.cim, ledger, n_cols=1 << 7)
    assert set(res["sites"]) == set(ledger.sites())
    # the sweep can only improve on (or match) the base deployment
    assert res["pj"] <= res["base_pj"]
    for s in res["sites"].values():
        assert s.get("granularity") in ("row", "unit", "conv", None)
    # the composed config resolves to the winning designs
    for site, s in res["sites"].items():
        if "granularity" in s:
            assert res["config"].for_site(site).granularity == \
                s["granularity"]


def test_recording_is_inert_outside_context():
    """cim_matmul outside a recording context must not accumulate state
    (the serving/training hot paths pay one list check, nothing else)."""
    arch = _tiny()
    params = init_params(jax.random.PRNGKey(0), arch)
    toks = jnp.ones((1, 4), jnp.int32)
    forward(params, toks, arch)               # no context active
    led = costs.CostLedger()
    with costs.recording(led):
        jax.eval_shape(lambda p, t: forward(p, t, arch), params, toks)
    assert len(led) > 0
    n = len(led)
    forward(params, toks, arch)               # after the context closed
    assert len(led) == n
