"""Request-API net: SamplingParams is the single entry for per-request
knobs; the legacy kwargs must convert bit-identically under a
DeprecationWarning; engine-level max_tokens must free slots with reason
"length"; seeded per-lane sampling must be placement-independent; and
StepResult.outputs must carry the typed per-request stream."""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig
from repro.serving.params import RequestOutput, SamplingParams
from repro.serving.scheduler import Scheduler, SchedulerConfig, StepClock


def _setup(name="qwen2-1.5b", seed=0):
    arch = get_config(name).reduced()
    params = init_params(jax.random.PRNGKey(seed), arch)
    return arch, params


def _engine(arch, params, **cfg_kw):
    return Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64,
                                            **cfg_kw))


def test_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(max_tokens=0)
    with pytest.raises(ValueError):
        SamplingParams(spec_k=0)
    p = SamplingParams(max_tokens=4)
    assert p.replace(max_tokens=8).max_tokens == 8
    assert p.max_tokens == 4          # frozen: replace returns a copy


def test_legacy_eos_kwarg_equivalent_and_warns():
    """add_request(eos_id=...) warns once and behaves bit-identically to
    params=SamplingParams(eos_id=...)."""
    arch, params = _setup()

    def gen(legacy):
        eng = _engine(arch, params)
        ref, _ = _stream(eng, [5, 6, 7], 12)
        eos = ref[4]
        eng2 = _engine(arch, params)
        if legacy:
            with pytest.warns(DeprecationWarning):
                slot = eng2.add_request([5, 6, 7], eos_id=eos)
        else:
            slot = eng2.add_request([5, 6, 7],
                                    params=SamplingParams(eos_id=eos))
        toks, _ = _stream(eng2, [5, 6, 7], 12, slot=slot)
        return toks, eng2.finish_reason(slot)

    old = gen(legacy=True)
    new = gen(legacy=False)
    assert old == new
    assert old[1] == "eos"


def _stream(eng, prompt, n, slot=None):
    if slot is None:
        slot = eng.add_request(prompt)
    while eng.active[slot] and len(eng.tokens[slot]) - len(prompt) < n:
        eng.step()
    return eng.tokens[slot][len(prompt):][:n], slot


def test_legacy_and_params_together_raises():
    arch, params = _setup()
    eng = _engine(arch, params)
    with pytest.raises(ValueError):
        eng.add_request([1, 2], eos_id=5, params=SamplingParams(eos_id=5))
    clk = StepClock()
    sched = Scheduler(_engine(arch, params), SchedulerConfig(),
                      clock=clk.now)
    with pytest.raises(ValueError):
        sched.submit([1, 2], max_new_tokens=4,
                     params=SamplingParams(max_tokens=4))


def test_scheduler_legacy_submit_equivalent_and_warns():
    arch, params = _setup()

    def run(legacy):
        eng = _engine(arch, params)
        clk = StepClock()
        sched = Scheduler(eng, SchedulerConfig(), clock=clk.now)
        if legacy:
            with pytest.warns(DeprecationWarning):
                r = sched.submit([4, 5, 6], max_new_tokens=5)
        else:
            r = sched.submit([4, 5, 6],
                             params=SamplingParams(max_tokens=5))
        while not sched.idle():
            sched.step()
            clk.tick()
        return list(r.generated), r.finish_reason

    assert run(True) == run(False)
    toks, reason = run(False)
    assert len(toks) == 5 and reason == "length"


def test_max_tokens_frees_slot_with_length_reason():
    """The engine caps generation at max_tokens, records "length", frees
    the slot the same step, and the slot is immediately reclaimable."""
    arch, params = _setup("mamba2-1.3b")
    eng = _engine(arch, params)
    ref, _ = _stream(eng, [2, 7, 1], 8)
    eng2 = _engine(arch, params)
    slot = eng2.add_request([2, 7, 1], params=SamplingParams(max_tokens=3))
    finished = []
    for _ in range(4):
        finished += eng2.step().finished
    assert eng2.tokens[slot][3:] == ref[:3]
    assert eng2.finish_reason(slot) == "length"
    assert slot in finished
    assert eng2.free_slots() == eng2.cfg.batch_slots
    # max_tokens=1: finished at prefill time, surfaced via the next step
    eng3 = _engine(arch, params)
    s3 = eng3.add_request([2, 7, 1], params=SamplingParams(max_tokens=1))
    assert not eng3.active[s3]
    res = eng3.step()
    assert s3 in res.finished
    assert eng3.finish_reason(s3) == "length"
    assert eng3.tokens[s3][3:] == ref[:1]


def test_per_request_temperature_mixed_batch():
    """A temperature-0 request inside a sampled batch decodes exact
    greedy; the sampled lane emits valid ids."""
    arch, params = _setup()
    eng = _engine(arch, params)
    ref, _ = _stream(eng, [9, 8, 7], 8)
    eng2 = _engine(arch, params)
    s0 = eng2.add_request([9, 8, 7], params=SamplingParams(temperature=0.0))
    s1 = eng2.add_request([1, 2, 3], params=SamplingParams(temperature=1.0),
                          key=jax.random.PRNGKey(5))
    for i in range(7):
        eng2.step(jax.random.PRNGKey(i))
    assert eng2.tokens[s0][3:][:8] == ref
    assert all(0 <= t < arch.vocab_size for t in eng2.tokens[s1][3:])


def test_seeded_sampling_placement_independent():
    """A seeded request's sampled stream depends only on its seed and
    event count — not on which slot it lands in, what per-step keys the
    caller passes, or what other traffic shares the batch."""
    arch, params = _setup()
    prompt = [3, 1, 4, 1]
    sp = SamplingParams(temperature=0.8, seed=123)

    def gen(slot_of, step_keys, extra):
        eng = Engine(arch, params, ServeConfig(batch_slots=3, max_ctx=64))
        slots = []
        if extra:   # competing unseeded+seeded traffic in lower slots
            slots.append(eng.add_request(
                [7, 7], params=SamplingParams(temperature=0.5, seed=9)))
        s = eng.add_request(prompt, params=sp)
        assert s == slot_of
        for i in range(6):
            k = jax.random.PRNGKey(100 + i) if step_keys else None
            eng.step(k)
        return eng.tokens[s][len(prompt):]

    a = gen(0, step_keys=False, extra=False)
    b = gen(1, step_keys=True, extra=True)
    assert a == b
    assert len(set(a)) > 1 or len(a) > 0   # stream exists
    # a different seed gives a different stream
    sp = SamplingParams(temperature=0.8, seed=124)
    c = gen(0, step_keys=False, extra=False)
    assert c != a


def test_step_result_outputs_typed_stream():
    """StepResult.outputs mirrors the raw dict as typed RequestOutput
    records, including finish reasons and the lazy energy thunk."""
    arch, params = _setup()
    eng = _engine(arch, params)
    s0 = eng.add_request([1, 2, 3])
    res = eng.step()
    assert isinstance(res.outputs[0], RequestOutput)
    by_slot = {o.slot: o for o in res.outputs}
    assert by_slot[s0].tokens == [res[s0]]
    assert not by_slot[s0].finished and by_slot[s0].finish_reason is None
    assert by_slot[s0].pj_per_token is None        # CIM off: no pricing
    # a capped request's terminal output carries the reason
    s1 = eng.add_request([4, 5], params=SamplingParams(max_tokens=2))
    res = eng.step()
    o1 = {o.slot: o for o in res.outputs}[s1]
    assert o1.finished and o1.finish_reason == "length"
