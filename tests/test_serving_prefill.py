"""Chunked-prefill regression net: the bucketed path must be
decode-equivalent to the legacy token-by-token prefill on every cache
family (attention KV, local ring buffer, RG-LRU state, SSM state, MoE
capacity routing), must issue O(log) dispatches, and the decode step must
move exactly one array to the host."""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, prefill_step
from repro.serving.engine import Engine, ServeConfig

# one config per cache-merge family the engine serves
ARCHS = [
    ("attn", "qwen2-1.5b"),
    ("rglru", "recurrentgemma-9b"),   # rglru + local ring layers
    ("ssm", "mamba2-1.3b"),
    ("moe", "grok-1-314b"),
]


def _setup(name, seed=0):
    arch = get_config(name).reduced()
    params = init_params(jax.random.PRNGKey(seed), arch)
    return arch, params


def _greedy(arch, params, prompt, n, **cfg_kw):
    eng = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64,
                                           **cfg_kw))
    slot = eng.add_request(prompt)
    toks = [eng.step()[slot] for _ in range(n)]
    return toks, eng


@pytest.mark.parametrize("label,name", ARCHS)
def test_chunked_prefill_matches_token_prefill(label, name):
    """A bucket-padded prefill (11 tokens -> one 16-token dispatch) must
    reproduce the token-by-token greedy continuation exactly."""
    arch, params = _setup(name)
    prompt = [int(t) for t in
              np.random.RandomState(0).randint(1, arch.vocab_size, 11)]
    got, eng_b = _greedy(arch, params, prompt, 6, prefill_mode="bucketed",
                         prefill_bucket_min=4)
    ref, eng_t = _greedy(arch, params, prompt, 6, prefill_mode="token")
    assert got == ref, (label, got, ref)
    assert eng_b.stats["prefill_dispatches"] == 1
    assert eng_t.stats["prefill_dispatches"] == len(prompt)


def test_multi_chunk_prefill_matches_token_prefill():
    """Prompts longer than prefill_bucket_max split into several bucketed
    dispatches; the chunk boundaries must be invisible to decode."""
    arch, params = _setup("qwen2-1.5b")
    prompt = [int(t) for t in
              np.random.RandomState(1).randint(1, arch.vocab_size, 21)]
    got, eng = _greedy(arch, params, prompt, 5, prefill_mode="bucketed",
                       prefill_bucket_max=8)
    ref, _ = _greedy(arch, params, prompt, 5, prefill_mode="token")
    assert got == ref
    assert eng.stats["prefill_dispatches"] == math.ceil(21 / 8)


def test_chunk_longer_than_local_window():
    """gemma3's sliding-window ring buffer: a single prefill chunk longer
    than the window overwrites ring slots early queries still attend —
    the chunk path must score against the pre-write ring."""
    arch = get_config("gemma3-1b").reduced()   # window 64
    params = init_params(jax.random.PRNGKey(0), arch)
    prompt = [int(t) for t in
              np.random.RandomState(2).randint(1, arch.vocab_size, 70)]

    def gen(mode):
        eng = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=256,
                                               prefill_mode=mode))
        slot = eng.add_request(prompt)
        return [eng.step()[slot] for _ in range(5)]

    assert gen("bucketed") == gen("token")


def test_prefill_into_live_batch():
    """A request joining mid-stream is prefilled with every other lane
    frozen inside the dispatch (length 0) — the incumbent's continuation
    and the joiner's solo continuation must both be preserved."""
    arch, params = _setup("recurrentgemma-9b")

    solo = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    solo.add_request([9, 8, 7])
    ref_joiner = [solo.step()[0] for _ in range(5)]

    incumbent = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    incumbent.add_request([1, 2, 3, 4, 5, 6])
    ref_incumbent = [incumbent.step()[0] for _ in range(8)]

    eng = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    eng.add_request([1, 2, 3, 4, 5, 6])
    inc = [eng.step()[0] for _ in range(3)]
    s1 = eng.add_request([9, 8, 7])          # prefills alongside live slot 0
    steps = [eng.step() for _ in range(5)]
    inc += [o[0] for o in steps]
    joiner = [o[s1] for o in steps]
    assert joiner == ref_joiner
    assert inc == ref_incumbent


def test_prefill_dispatch_count_log_bounded():
    """add_request must issue at most ceil(log2(len)) + 1 compiled
    dispatches for prompts that fit the context (acceptance bound)."""
    arch, params = _setup("qwen2-1.5b")
    for n in (1, 2, 7, 13, 31):
        eng = Engine(arch, params, ServeConfig(batch_slots=1, max_ctx=64))
        eng.add_request(list(range(1, n + 1)))
        bound = math.ceil(math.log2(n)) + 1 if n > 1 else 1
        assert eng.stats["prefill_dispatches"] <= bound, (n, eng.stats)


def test_prompt_must_leave_decode_room():
    """A prompt of max_ctx tokens has no cache position left for the first
    decode write (which would clamp onto the last prompt entry and corrupt
    the lane) — add_request must reject it up front."""
    arch, params = _setup("qwen2-1.5b")
    eng = Engine(arch, params, ServeConfig(batch_slots=1, max_ctx=8))
    with pytest.raises(ValueError, match="max_ctx"):
        eng.add_request(list(range(1, 9)))
    eng.add_request(list(range(1, 8)))     # max_ctx - 1 is fine
    assert 0 in eng.step()


def test_decode_step_single_host_transfer(monkeypatch):
    """The fused decode moves exactly one (batch_slots,) int32 array of
    sampled ids to the host per step — logits stay on device."""
    arch, params = _setup("qwen2-1.5b")
    eng = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    eng.add_request([3, 1, 4, 1, 5])

    calls = []
    orig = Engine._fetch

    def counting_fetch(ids_dev):
        out = orig(ids_dev)
        calls.append(out)
        return out

    monkeypatch.setattr(Engine, "_fetch", staticmethod(counting_fetch))
    out = eng.step()
    assert len(calls) == 1
    assert calls[0].shape == (2,) and calls[0].dtype == np.int32
    assert out[0] == int(calls[0][0])


def test_temperature_sampling_on_device():
    """Categorical sampling is fused in the decode executable: valid ids,
    reproducible under the same key, varying across keys."""
    arch, params = _setup("qwen2-1.5b")

    def gen(seed):
        eng = Engine(arch, params, ServeConfig(batch_slots=1, max_ctx=64,
                                               temperature=0.8))
        # keyless add_request under temperature > 0 would warn + argmax
        eng.add_request([5, 6, 7], key=jax.random.PRNGKey(seed + 7))
        return [eng.step(jax.random.PRNGKey(seed + i))[0] for i in range(6)]

    a, b, c = gen(0), gen(0), gen(100)
    assert a == b
    assert a != c  # astronomically unlikely to collide on 6 draws
    assert all(0 <= t < arch.vocab_size for t in a + c)


def test_prefill_step_frozen_lane_bitwise():
    """prefill_step with length 0 on a lane returns that lane's cache
    bitwise unchanged — the contract that lets the engine skip merging."""
    from repro.models import init_cache

    arch, params = _setup("mamba2-1.3b")
    cache = init_cache(arch, 2, 32, dtype=np.float32)
    # advance lane 1 first so its state is nonzero
    toks = np.zeros((2, 8), np.int32)
    toks[1, :] = np.arange(1, 9)
    _, _, cache = jax.jit(
        lambda p, t, c, i, l: prefill_step(p, t, arch, c, i, l))(
        params, toks, cache, np.zeros(2, np.int32),
        np.array([0, 8], np.int32))
    before = jax.tree.map(lambda a: np.asarray(a), cache)
    # now prefill lane 0; lane 1 must be untouched
    toks2 = np.zeros((2, 8), np.int32)
    toks2[0, :5] = [9, 8, 7, 6, 5]
    _, _, cache2 = jax.jit(
        lambda p, t, c, i, l: prefill_step(p, t, arch, c, i, l))(
        params, toks2, cache, np.array([0, 8], np.int32),
        np.array([5, 0], np.int32))
    after = jax.tree.map(lambda a: np.asarray(a), cache2)

    def lane(tree, b):
        # stacked superblock caches carry batch on axis 1; tail on axis 0
        sup = jax.tree.leaves(jax.tree.map(lambda a: a[:, b], tree.get(
            "superblocks", {})))
        tail = jax.tree.leaves(jax.tree.map(lambda a: a[b], tree.get(
            "tail", {})))
        return sup + tail

    for x, y in zip(lane(before, 1), lane(after, 1)):
        np.testing.assert_array_equal(x, y)
    assert any(np.any(x != y)
               for x, y in zip(lane(before, 0), lane(after, 0)))
