"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cim_config import CIMConfig
from repro.core import formats as F
from repro.kernels.ops import cim_matmul
from repro.kernels.ref import grmac_matmul_ref
from repro.kernels.tiled import grmac_matmul_tiled


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1 << 30), scale=st.floats(0.1, 100.0),
       mode=st.sampled_from(["fakequant", "grmac"]))
def test_cim_matmul_scale_equivariance(seed, scale, mode):
    """Dynamic pre-scale makes the op exactly scale-equivariant: the
    normalized inputs are identical, so out(c·x) == c·out(x)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (8, 64))
    w = jax.random.normal(kw, (64, 16))
    cfg = CIMConfig(mode=mode)
    o1 = cim_matmul(x, w, cfg, use_kernel=False)
    o2 = cim_matmul(x * scale, w, cfg, use_kernel=False)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1) * scale,
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1 << 30),
       gran=st.sampled_from(["row", "unit", "conv"]))
def test_grmac_ideal_adc_equals_exact_quantized_product(seed, gran):
    """With a near-ideal ADC the GR-MAC block simulation reduces to the
    exact quantized matmul (the paper's reconstruction identity)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (16, 64), minval=-1, maxval=1)
    w = F.quantize(jax.random.uniform(kw, (64, 8), minval=-1, maxval=1),
                   F.FP4_E2M1)
    out = grmac_matmul_ref(x, w, fmt_x=F.FP6_E3M2, fmt_w=F.FP4_E2M1,
                           n_r=32, enob=28.0, granularity=gran)
    ref = F.quantize(x, F.FP6_E3M2) @ w
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1 << 30),
       gran=st.sampled_from(["row", "unit", "conv"]),
       m=st.integers(1, 70),
       n=st.integers(1, 50),
       blocks=st.integers(1, 4),
       n_r=st.sampled_from([4, 8, 32, 96]),   # one element shy .. whole K
       tile_m=st.sampled_from([1, 8, 13, 32, 256]),
       tile_n=st.sampled_from([0, 8, 13]),
       bf16=st.booleans())
def test_tiled_bit_identical_to_ref(seed, gran, m, n, blocks, n_r,
                                    tile_m, tile_n, bf16):
    """The fused tiled backend is the oracle, bit for bit (0 ulp), across
    granularities, tile sizes that do and don't divide M/N, n_r edge
    values (one block per row through many narrow columns), and the bf16
    values-einsum flag (FP6_E3M2 x FP4_E2M1 products are bf16-exact)."""
    k = blocks * n_r
    kx, kw_ = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (m, k), minval=-1, maxval=1)
    w = F.quantize(jax.random.uniform(kw_, (k, n), minval=-1, maxval=1),
                   F.FP4_E2M1)
    kw = dict(fmt_x=F.FP6_E3M2, fmt_w=F.FP4_E2M1, n_r=n_r, enob=8.0,
              granularity=gran)
    ref = grmac_matmul_ref(x, w, **kw)
    out = grmac_matmul_tiled(x, w, tile_m=tile_m, tile_n=tile_n,
                             bf16_values=bf16, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1 << 30), ne=st.integers(1, 4),
       nm=st.integers(1, 4))
def test_quantize_monotone(seed, ne, nm):
    """Quantization preserves order (monotone non-decreasing map)."""
    fmt = F.FPFormat(ne, nm)
    x = jnp.sort(jax.random.uniform(jax.random.PRNGKey(seed), (256,),
                                    minval=-1, maxval=1))
    xq = F.quantize(x, fmt)
    assert bool(jnp.all(jnp.diff(xq) >= -1e-9))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1 << 30), enob=st.floats(2.0, 12.0))
def test_adc_noise_bound(seed, enob):
    """|Q_ADC(v) - v| <= Δ/2 for v in [-1, 1]."""
    from repro.core.mac import adc_quantize
    v = jax.random.uniform(jax.random.PRNGKey(seed), (512,),
                           minval=-1, maxval=1)
    vq = adc_quantize(v, enob)
    delta = 2.0 / 2 ** enob
    assert float(jnp.max(jnp.abs(vq - v))) <= delta / 2 + 1e-7
