"""Per-slot EOS handling: a lane that emits its EOS frees its slot at that
step (not at max_ctx), ``Engine.step`` reports the freed slots, and a freed
slot is immediately claimable by ``add_request``.

The sampled ids are scripted through ``Engine._fetch`` (the engine's single
device->host transfer), so mixed-length completions are deterministic and
independent of the untrained model's actual argmax stream — the test pins
the engine's *bookkeeping*, which is what this feature adds."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig, StepResult

EOS = 7


def _engine(batch_slots=3, max_ctx=32, **cfg_kw):
    arch = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), arch)
    return Engine(arch, params,
                  ServeConfig(batch_slots=batch_slots, max_ctx=max_ctx,
                              **cfg_kw))


def _script_fetch(monkeypatch, script):
    """Replace the sampled ids of step t with ``script[t]`` (later steps
    reuse the last row). The decode still runs; only the host-visible ids
    are overridden."""
    it = {"t": 0}

    def fake_fetch(ids_dev):
        row = script[min(it["t"], len(script) - 1)]
        it["t"] += 1
        return np.asarray(row, np.int32)

    monkeypatch.setattr(Engine, "_fetch", staticmethod(fake_fetch))


def test_mixed_length_batch_frees_slots_in_order(monkeypatch):
    """Three lanes finishing at different steps must free their slots in
    completion order, each exactly at its EOS step."""
    eng = _engine()
    for p in ([3, 1], [4, 1, 5], [9, 2]):
        eng.add_request(p, eos_id=EOS)
    # slot 0 hits EOS at step 1, slot 2 at step 2, slot 1 at step 3
    _script_fetch(monkeypatch, [
        [11, 12, 13],
        [EOS, 14, 15],
        [16, 17, EOS],
        [18, EOS, 19],
    ])
    s0 = eng.step()
    assert isinstance(s0, StepResult) and s0.finished == []
    assert sorted(s0) == [0, 1, 2]

    s1 = eng.step()
    assert s1.finished == [0]
    assert list(eng.active) == [False, True, True]
    assert eng.tokens[0][-1] == EOS          # the EOS itself is kept

    s2 = eng.step()
    assert s2.finished == [2]
    assert 0 not in s2                       # freed lane emits nothing
    assert sorted(s2) == [1, 2]              # EOS step still reports the token

    s3 = eng.step()
    assert s3.finished == [1]
    assert not eng.active.any()
    assert eng.step() == {}                  # fully drained engine is a no-op


def test_freed_slot_is_immediately_claimable(monkeypatch):
    eng = _engine(batch_slots=2)
    eng.add_request([3, 1, 4], eos_id=EOS)
    eng.add_request([5, 9], eos_id=EOS)
    _script_fetch(monkeypatch, [[EOS, 21], [22, 23]])
    out = eng.step()
    assert out.finished == [0]
    assert eng.add_request([8, 8]) == 0      # the freed slot, immediately
    assert list(eng.active) == [True, True]
    with pytest.raises(RuntimeError):        # both lanes live again -> full
        eng.add_request([1, 2])


def test_config_level_eos_and_max_ctx_interplay(monkeypatch):
    """cfg.eos_id applies to every request; lanes that never emit EOS still
    free at max_ctx (the legacy completion path, now reported too)."""
    eng = _engine(batch_slots=2, max_ctx=6, eos_id=EOS)
    eng.add_request([1, 2])                  # cfg-level EOS
    eng.add_request([3, 4], eos_id=10**9)    # per-request override: never hits
    _script_fetch(monkeypatch, [[31, 41], [EOS, 42], [33, 43], [34, 44]])
    assert eng.step().finished == []
    assert eng.step().finished == [0]        # EOS from cfg default
    assert eng.step().finished == []
    assert eng.step().finished == [1]        # lengths: 2 prompt + 4 = max_ctx
    assert not eng.active.any()


def test_reused_slot_carries_no_state_from_previous_request():
    """A recurrent-state arch (RG-LRU) must generate identically on a
    reused slot and on a fresh engine: the previous occupant's recurrent
    state is zeroed at claim time (attention KV alone is length-masked,
    recurrent caches are not)."""
    arch = get_config("recurrentgemma-9b").reduced()
    params = init_params(jax.random.PRNGKey(0), arch)
    cfg = ServeConfig(batch_slots=1, max_ctx=12)
    prompt_b = [9, 8, 7]

    fresh = Engine(arch, params, cfg)
    slot = fresh.add_request(prompt_b)
    want = [fresh.step()[slot] for _ in range(5)]

    eng = Engine(arch, params, cfg)
    eng.add_request([1, 2, 3, 4, 5])
    while eng.active.any():                  # drain request A to max_ctx
        eng.step()
    slot = eng.add_request(prompt_b)         # reuse the freed slot
    got = [eng.step()[slot] for _ in range(5)]
    assert got == want


def test_eos_as_first_prefill_token_finishes_and_surfaces(monkeypatch):
    """A request whose FIRST prefill-sampled token is the EOS completes
    before it ever joins a decode batch: the slot frees at add_request
    time, and the completion still surfaces through the next
    ``StepResult.finished`` (it previously was never reported anywhere)."""
    eng = _engine(batch_slots=2)
    _script_fetch(monkeypatch, [
        [11, 99],        # request A's first token
        [99, EOS],       # request B's first token == EOS: done at prefill
        [12, 98],        # decode step: lane A only
    ])
    a = eng.add_request([3, 1], eos_id=EOS)
    b = eng.add_request([4, 1, 5], eos_id=EOS)
    assert list(eng.active) == [True, False]     # B freed immediately
    assert eng.tokens[b][-1] == EOS              # the EOS itself is kept
    s = eng.step()
    assert s.finished == [b]                     # surfaced by the next step
    assert dict(s) == {a: 12}                    # A decodes undisturbed
    assert eng.step().finished == []             # reported exactly once


def test_eos_at_prefill_on_drained_engine_surfaces_via_noop_step(monkeypatch):
    """Even when the one-token completion leaves the engine empty, the
    no-op step must still report it (the early-return path carries the
    pending finishes too) — and the slot is claimable again."""
    eng = _engine(batch_slots=1)
    _script_fetch(monkeypatch, [[EOS]])
    slot = eng.add_request([3, 1, 4], eos_id=EOS)
    assert not eng.active.any()
    assert eng.stats["decode_steps"] == 0        # never joined a batch
    s = eng.step()
    assert dict(s) == {} and s.finished == [slot]
    assert eng.step().finished == []
    assert eng.add_request([5, 9]) == slot       # free for reuse


def test_no_eos_keeps_legacy_behavior(monkeypatch):
    """Without any EOS configured, lanes decode to max_ctx exactly as
    before — and the context-exhaustion free is reported in finished."""
    eng = _engine(batch_slots=1, max_ctx=5)
    eng.add_request([1, 2, 3])
    _script_fetch(monkeypatch, [[EOS]])      # EOS id emitted but not configured
    assert eng.step().finished == []         # not finished: no EOS set
    assert eng.step().finished == [0]        # 3 prompt + 2 decodes = max_ctx
