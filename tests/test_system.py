"""End-to-end system behaviour: serving engine, energy reporting, examples'
core flows, and CIM-mode QAT round trip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig, energy_report
from repro.training.optimizer import OptimizerConfig
from repro.training.trainer import TrainConfig, make_train_step
from repro.training.optimizer import init_opt_state


def _tiny_arch(cim_mode="off"):
    arch = get_config("paper-cim-120m").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=256, vocab_size=512)
    return arch.replace(cim=arch.cim.with_mode(cim_mode))


def test_engine_prefill_decode():
    arch = _tiny_arch()
    params = init_params(jax.random.PRNGKey(0), arch)
    eng = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    s0 = eng.add_request([1, 2, 3])
    s1 = eng.add_request([7, 8])
    # the first output token is sampled from the prefill logits (no
    # re-feed of the last prompt token), so add_request already emits one
    assert len(eng.tokens[s0]) == 3 + 1
    toks = []
    for _ in range(8):
        out = eng.step()
        toks.append(out)
    assert all(s0 in o and s1 in o for o in toks)
    assert len(eng.tokens[s0]) == 3 + 1 + 8
    assert all(0 <= t < arch.vocab_size for t in eng.tokens[s0])


def test_engine_decode_deterministic_greedy():
    arch = _tiny_arch()
    params = init_params(jax.random.PRNGKey(0), arch)
    def gen():
        eng = Engine(arch, params, ServeConfig(batch_slots=1, max_ctx=32))
        eng.add_request([5, 6, 7])
        return [eng.step()[0] for _ in range(6)]
    assert gen() == gen()


def test_energy_report_cim_vs_conventional():
    arch = _tiny_arch("grmac")
    rep = energy_report(arch)
    assert rep["enabled"]
    assert rep["fj_per_op"] > 0
    assert rep["conventional_fj_per_op"] > rep["fj_per_op"]  # the paper's win
    assert rep["pj_per_token"] > 0


def test_qat_grmac_train_step_descends():
    arch = _tiny_arch("fakequant")
    params = init_params(jax.random.PRNGKey(0), arch)
    ocfg = OptimizerConfig(lr=2e-3, warmup_steps=2, total_steps=20)
    state = init_opt_state(params, ocfg)
    step = jax.jit(make_train_step(arch, TrainConfig(opt=ocfg)))
    pipe = SyntheticLM(DataConfig(global_batch=4, seq_len=32,
                                  vocab_size=arch.vocab_size))
    losses = []
    for s in range(8):
        params, state, m = step(params, state, pipe.batch_at(s))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert min(losses[-3:]) < losses[0]


def test_vocab_padding_logits_masked():
    """Odd vocab sizes pad to 256-multiples; pad logits can never win."""
    arch = _tiny_arch().replace(vocab_size=500)  # pads to 512
    assert arch.padded_vocab == 512
    params = init_params(jax.random.PRNGKey(0), arch)
    assert params["lm_head"]["w"].shape == (arch.d_model, 512)
    from repro.models import forward
    toks = jnp.ones((2, 8), jnp.int32)
    logits, _, _ = forward(params, toks, arch)
    assert logits.shape[-1] == 512
    assert float(jnp.max(logits[..., 500:])) < -1e29  # masked
    assert int(jnp.max(jnp.argmax(logits, -1))) < 500


def test_fp8_kv_cache_decode():
    """FP8-E4M3 KV cache (beyond-paper, §Perf P3.1) stays numerically close
    to the bf16 cache on short decodes."""
    from repro.models import decode_step, forward, init_cache

    arch = _tiny_arch()
    params = init_params(jax.random.PRNGKey(0), arch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0,
                              arch.vocab_size)
    ref, _, _ = forward(params, toks, arch)
    cache = init_cache(arch, 1, 64, dtype=jnp.float8_e4m3fn)
    outs = []
    for t in range(10):
        lg, cache = decode_step(params, toks[:, t:t+1], arch, cache,
                                jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, 1).astype(jnp.float32)
    co = jnp.corrcoef(dec.ravel(), ref.astype(jnp.float32).ravel())[0, 1]
    assert float(co) > 0.98, float(co)


def test_engine_mixed_length_continuous_batching():
    """A slot joining mid-stream must generate the same tokens as it would
    alone (per-slot cache indices, §serving)."""
    arch = _tiny_arch()
    params = init_params(jax.random.PRNGKey(0), arch)

    # reference: slot alone
    eng_a = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    eng_a.add_request([9, 8, 7])
    ref = [eng_a.step()[0] for _ in range(5)]

    # same prompt decoded alongside a LONGER earlier request
    eng_b = Engine(arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    eng_b.add_request([1, 2, 3, 4, 5, 6])     # slot 0, longer
    s1 = eng_b.add_request([9, 8, 7])          # slot 1, shorter
    got = [eng_b.step()[s1] for _ in range(5)]
    assert got == ref, (got, ref)
