"""CLI for the static-analysis passes: ``python -m repro.analysis``.

Runs the jaxpr ledger audit over the requested configs × phases, plus the
engine invariant harness (unless ``--no-invariants``), and writes one
machine-readable JSON report. Exit status is the number of failing
configs' findings clamped to 1 — nonzero on any untagged MAC, ledger
mismatch, dtype-promotion flag, or invariant violation — so the CI audit
lane can gate on it directly.

    PYTHONPATH=src python -m repro.analysis --all-configs \
        --out experiments/audit/audit_report.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

__all__ = ["main", "build_report"]

DEFAULT_OUT = "experiments/audit/audit_report.json"
PHASES = ("prefill", "decode", "train")


def build_report(config_names: List[str], phases=PHASES, *,
                 invariants: bool = True, verbose: bool = True) -> dict:
    from repro.analysis import invariants as inv
    from repro.analysis.jaxpr_audit import audit_arch
    from repro.configs import get_config

    report = {"schema": 1, "phases": list(phases), "configs": {}}
    failures = 0
    for name in sorted(config_names):
        arch = get_config(name)
        res = audit_arch(arch, phases)
        report["configs"][name] = res
        failures += res["failures"]
        if verbose:
            tot = {k: sum(ph[k] for ph in res["phases"].values())
                   for k in ("dot_generals", "tagged_values",
                             "declared_digital", "untagged",
                             "ledger_mismatches")}
            print(f"[audit] {name}: {tot['dot_generals']} dots = "
                  f"{tot['tagged_values']} tagged + "
                  f"{tot['declared_digital']} declared-digital "
                  f"(+gains/transposes) | untagged={tot['untagged']} "
                  f"mismatches={tot['ledger_mismatches']} "
                  f"failures={res['failures']}")
    if invariants:
        res = inv.run_invariants()
        report["invariants"] = res
        failures += res["violations"]
        if verbose:
            print(f"[audit] invariants: {res['violations']} violations "
                  f"across {len(res['configs'])} configs")
        # the same compile/transfer rules must survive the continuous-
        # batching layer's interleaved prefill (repro.serving.scheduler)
        res = inv.run_scheduler_invariants()
        report["scheduler_invariants"] = res
        failures += res["violations"]
        if verbose:
            print(f"[audit] scheduler invariants: {res['violations']} "
                  f"violations across {len(res['configs'])} configs")
        # ... and under a hit-heavy prefix-cache trace: adopting cached
        # prefixes must not add compiles or host transfers
        res = inv.run_prefix_invariants()
        report["prefix_invariants"] = res
        failures += res["violations"]
        if verbose:
            print(f"[audit] prefix-cache invariants: {res['violations']} "
                  f"violations across {len(res['configs'])} configs")
        # ... and under speculative decode: greedy verify/repair must
        # reuse admission bucket executables (zero compiles beyond the
        # drafter's own) and repair must fetch nothing
        res = inv.run_spec_invariants()
        report["spec_invariants"] = res
        failures += res["violations"]
        if verbose:
            print(f"[audit] speculative invariants: {res['violations']} "
                  f"violations across {len(res['configs'])} configs")
    report["failures"] = failures
    return report


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Ledger-completeness audit + hot-path invariant checks")
    ap.add_argument("--configs", nargs="*", default=None,
                    help="config names to audit (default: paper-cim-120m)")
    ap.add_argument("--all-configs", action="store_true",
                    help="audit every registered config")
    ap.add_argument("--phases", nargs="*", default=list(PHASES),
                    choices=list(PHASES))
    ap.add_argument("--out", default=None,
                    help=f"write the JSON report (CI uses {DEFAULT_OUT})")
    ap.add_argument("--no-invariants", action="store_true",
                    help="skip the engine invariant harness")
    args = ap.parse_args(argv)

    from repro.configs import list_configs
    if args.all_configs:
        names = list(list_configs())
    elif args.configs:
        names = list(args.configs)
    else:
        names = ["paper-cim-120m"]

    report = build_report(names, tuple(args.phases),
                          invariants=not args.no_invariants)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[audit] report -> {args.out}")
    if report["failures"]:
        print(f"[audit] FAILED: {report['failures']} findings",
              file=sys.stderr)
        return 1
    print("[audit] OK: ledger complete, invariants hold")
    return 0
