"""Hot-path invariant checks for the serving engine.

Two properties were won in earlier iterations and must never regress
silently (see the "Machine-checked invariants" section of
``serving/engine.py``):

1. **Compile budget** — at most ONE trace per (arch, sampling-mode) decode
   executable and per (arch, bucket) prefill executable. Shape drift,
   accidental weak keys, or a per-engine ``jax.jit`` would show up as a
   second trace of the same key.
2. **One D2H transfer per decode step** — the host sees exactly one
   ``(batch_slots,)`` int32 fetch per ``step`` (and per prefill
   first-token selection), all routed through ``Engine._fetch``. (A
   ``jax.transfer_guard`` cannot enforce this on the CPU backend — it is
   a no-op there — so the harness counts the designed transfer point
   instead.)

``InstrumentedEngine`` interposes on the engine's dedicated seams
(``_compiled_decode`` / ``_compiled_prefill`` / ``_fetch``): the raw step
bodies are wrapped in a trace counter *before* jitting, so every
(re)trace increments a counter while the compiled fast path stays
untouched. ``check()`` raises ``InvariantViolation`` on any breach;
``run_invariants`` drives a deterministic serve script over a reduced
arch subset covering the attention, RG-LRU and SSM cache families.

``run_scheduler_invariants`` drives the same properties through the
continuous-batching layer (``repro.serving.scheduler``): seeded Poisson
traffic with a small prefill token budget, so prefill chunks genuinely
interleave between decode steps, mid-prefill lanes ride frozen through
decode dispatches, and freed slots are reclaimed — all without a single
retrace, extra transfer, or new bucket executable beyond what the
blocking path would compile. The incremental prefill API routes through
the exact seams the harness instruments (``advance_prefill`` →
``_compiled_prefill``; ``finish_prefill`` → ``_fetch``), so the counters
need no scheduler-specific hooks.

``run_prefix_invariants`` re-proves both properties under a hit-heavy
prefix-cache trace (``repro.serving.prefix_cache``): cached-prefix
adoption and boundary-snapshot insertion are device-side and
chunk-aligned to ``prefill_bucket_min``, so hits must add zero new
bucket executables and zero host transfers.

``run_spec_invariants`` extends the audit to speculative decode
(``repro.serving.speculative``): the "self" drafter must BE the decode
executable (same jit key, zero compiles of its own), greedy verify and
repair chunks must reuse bucket executables admission prefill already
compiled (checked in place, at dispatch time, by the instrumented
``verify_chunk``/``repair_chunk``), and the transfer ledger must close
as ``fetches == admissions + sequential steps + draft dispatches +
verify dispatches`` — a repair dispatch re-feeds tokens acceptance
already knows and crosses *nothing* back to the host. A forced-mismatch
drive (every draft wrong) pins the rollback/repair path per cache
family, and a sampled drive confirms the rejection-rule verify is one
executable per bucket, traced once.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

from repro.serving.engine import (Engine, ServeConfig, _decode_raw,
                                  _prefill_raw, _verify_raw)

__all__ = ["InvariantViolation", "InstrumentedEngine", "run_invariants",
           "run_scheduler_invariants", "run_prefix_invariants",
           "run_spec_invariants", "INVARIANT_CONFIGS"]

# Reduced-arch subset covering the three cache families (attention KV,
# RG-LRU recurrent, SSM state) — the shapes that have historically driven
# retraces and extra transfers.
INVARIANT_CONFIGS = ("qwen2-1.5b", "recurrentgemma-9b", "mamba2-1.3b")


class InvariantViolation(AssertionError):
    """A machine-checked hot-path invariant was breached."""


class InstrumentedEngine(Engine):
    """Engine with compile/transfer counters on the hot-path seams.

    Uses engine-local jits (one per key) wrapping the *same* raw bodies
    the production executables compile, so a retrace of any key is a real
    retracing regression, not cache pollution from other engines/tests.
    """

    def __init__(self, *args, **kwargs):
        self.trace_counts: Dict[str, int] = {}
        self.fetches = 0
        self.steps_checked = 0
        self._jits: Dict[str, object] = {}
        super().__init__(*args, **kwargs)

    def _counting_jit(self, key: str, raw):
        if key not in self._jits:
            counts = self.trace_counts

            def counted(*a, **kw):
                counts[key] = counts.get(key, 0) + 1
                return raw(*a, **kw)

            self._jits[key] = jax.jit(counted)
        return self._jits[key]

    def _compiled_decode(self, sample: bool):
        return self._counting_jit(f"decode[sample={sample}]",
                                  _decode_raw(self.arch, sample))

    def _compiled_prefill(self, bucket: int):
        return self._counting_jit(f"prefill[bucket={bucket}]",
                                  _prefill_raw(self.arch, bucket))

    def _compiled_draft(self, draft_arch):
        # the "self" draft policy must reuse the greedy decode executable
        # (same key), exactly as the production cache does — a separate
        # key here would hide a real extra compile
        if draft_arch is self.arch:
            return self._compiled_decode(False)
        return self._counting_jit("draft[sample=False]",
                                  _decode_raw(draft_arch, False))

    def _compiled_verify(self, bucket: int):
        return self._counting_jit(f"verify[bucket={bucket}]",
                                  _verify_raw(self.arch, bucket))

    def _require_compiled_bucket(self, what: str, k: int) -> None:
        key = f"prefill[bucket={self._bucket(k)}]"
        if key not in self._jits:
            raise InvariantViolation(
                f"{what} chunk needed a fresh {key} executable: greedy "
                "speculative verification must reuse the bucket "
                "executables admission prefill already compiled")

    def verify_chunk(self, chunk: np.ndarray,
                     lens: np.ndarray) -> np.ndarray:
        self._require_compiled_bucket("verify", chunk.shape[1])
        return super().verify_chunk(chunk, lens)

    def repair_chunk(self, chunk: np.ndarray, lens: np.ndarray,
                     index: np.ndarray) -> None:
        self._require_compiled_bucket("repair", chunk.shape[1])
        return super().repair_chunk(chunk, lens, index)

    def _fetch(self, ids_dev) -> np.ndarray:  # instance over staticmethod
        self.fetches += 1
        return Engine._fetch(ids_dev)

    def step(self, key: Optional[jax.Array] = None):
        before = self.fetches
        live = bool(self.active.any())
        result = super().step(key)
        delta = self.fetches - before
        want = 1 if live else 0
        if delta != want:
            raise InvariantViolation(
                f"decode step performed {delta} device->host transfers "
                f"(expected exactly {want}): every host-visible value must "
                "route through the single Engine._fetch of sampled ids")
        self.steps_checked += 1
        return result

    def check(self) -> dict:
        """Assert the compile budget; return the counter report."""
        over = {k: c for k, c in self.trace_counts.items() if c > 1}
        if over:
            raise InvariantViolation(
                f"executables traced more than once: {over} — a retrace "
                "of a cached (arch, bucket)/(arch, sample) key means the "
                "jit key or input shapes drifted (the PR-1 recompile bug)")
        if not self.trace_counts:
            raise InvariantViolation("harness ran nothing: no traces seen")
        return {
            "traces": dict(sorted(self.trace_counts.items())),
            "compiles": sum(self.trace_counts.values()),
            "fetches": self.fetches,
            "steps": self.steps_checked,
        }


def _drive(arch_name: str, decode_steps: int = 4) -> dict:
    """One deterministic serve script: two prompts sharing a bucket, a
    decode burst, then a third request reusing the freed capacity — the
    same bucket and decode keys must serve all of it with one trace each."""
    from repro.configs import get_config
    from repro.models import init_params

    arch = get_config(arch_name).reduced()
    params = init_params(jax.random.PRNGKey(0), arch)
    eng = InstrumentedEngine(
        arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    eng.add_request([3, 1, 4, 1, 5])         # bucket 8
    eng.add_request([2, 7])                  # same bucket 8: no new trace
    for _ in range(decode_steps):
        eng.step()
    report = eng.check()
    n_prefill = sum(1 for k in eng.trace_counts if k.startswith("prefill"))
    n_decode = sum(1 for k in eng.trace_counts if k.startswith("decode"))
    if n_prefill != 1 or n_decode != 1:
        raise InvariantViolation(
            f"{arch_name}: expected 1 prefill + 1 decode executable, got "
            f"{dict(eng.trace_counts)}")
    # prefill fetches: one first-token selection per add_request
    if eng.fetches != 2 + eng.steps_checked:
        raise InvariantViolation(
            f"{arch_name}: {eng.fetches} fetches for 2 prefills + "
            f"{eng.steps_checked} steps (expected "
            f"{2 + eng.steps_checked})")
    return report


def run_invariants(configs=INVARIANT_CONFIGS) -> dict:
    """Run the invariant script over ``configs``; returns the JSON-able
    counter report. Raises ``InvariantViolation`` on any breach."""
    out: Dict[str, dict] = {}
    failures: List[str] = []
    for name in configs:
        try:
            out[name] = _drive(name)
        except InvariantViolation as e:   # keep auditing the rest
            out[name] = {"error": str(e)}
            failures.append(name)
    return {"configs": out, "violations": len(failures),
            "failed": failures}


def _drive_scheduler(arch_name: str, n_requests: int = 5) -> dict:
    """One deterministic scheduler traffic script over an instrumented
    engine: more requests than slots, a prefill token budget small enough
    that prompts drain across several decode iterations, and completion
    by ``max_new_tokens`` only (no EOS), so the dispatch schedule is a
    pure function of the seeded traffic. Checks scheduler-specific
    arithmetic on top of ``check()``: exactly one first-token fetch per
    admission, one decode executable, and every bucket executable traced
    at most once despite budget-truncated chunk lengths."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.scheduler import (
        Scheduler, SchedulerConfig, StepClock, run_open_loop, synth_traffic)

    arch = get_config(arch_name).reduced()
    params = init_params(jax.random.PRNGKey(0), arch)
    eng = InstrumentedEngine(
        arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    clock = StepClock()
    # budget 10 > bucket_min: long prompts slice into a bucket-16 chunk
    # plus a bucket-8 remainder, so two distinct bucket executables get
    # exercised across interleaved steps
    sched = Scheduler(eng, SchedulerConfig(prefill_token_budget=10),
                      clock=clock.now)
    traffic = synth_traffic(n_requests, 0.5, seed=0,
                            vocab_size=arch.vocab_size,
                            prompt_len=(3, 14), out_len=(2, 6))
    run_open_loop(sched, traffic, tick=clock.tick)
    report = eng.check()
    n_decode = sum(1 for k in eng.trace_counts if k.startswith("decode"))
    if n_decode != 1:
        raise InvariantViolation(
            f"{arch_name}: scheduler-driven serving traced {n_decode} "
            f"decode executables (expected 1): {dict(eng.trace_counts)}")
    done = [r for r in sched.finished if r.finish_reason != "rejected"]
    if len(done) != n_requests:
        raise InvariantViolation(
            f"{arch_name}: {len(done)}/{n_requests} requests completed "
            "under the scheduler")
    # one first-token selection per admission (re-admissions after
    # preemption would add theirs; this script never preempts) + one
    # fetch per decode step — nothing else may cross the device boundary
    want = sched.stats["admitted"] + eng.steps_checked
    if eng.fetches != want:
        raise InvariantViolation(
            f"{arch_name}: {eng.fetches} fetches for "
            f"{sched.stats['admitted']} admissions + {eng.steps_checked} "
            f"decode steps (expected {want})")
    report["completed"] = len(done)
    report["prefill_executables"] = sum(
        1 for k in eng.trace_counts if k.startswith("prefill"))
    return report


def run_scheduler_invariants(configs=INVARIANT_CONFIGS) -> dict:
    """Scheduler-layer invariant run (see ``_drive_scheduler``); same
    report shape as ``run_invariants``."""
    out: Dict[str, dict] = {}
    failures: List[str] = []
    for name in configs:
        try:
            out[name] = _drive_scheduler(name)
        except InvariantViolation as e:   # keep auditing the rest
            out[name] = {"error": str(e)}
            failures.append(name)
    return {"configs": out, "violations": len(failures),
            "failed": failures}


def _drive_prefix(arch_name: str, n_requests: int = 8) -> dict:
    """Hit-heavy prefix-cache trace through the instrumented engine:
    shared-prefix Zipf traffic with the cache enabled, so most
    admissions adopt a cached prefix (device-side restore) and prefill
    only suffixes. The compile budget must hold — adopted prefixes
    compose with the *same* bucket executables (chunk == bucket_min
    alignment), so a hit can never introduce a new bucket trace — and
    the fetch arithmetic is unchanged: one first-token selection per
    admission + one per decode step; snapshot capture/restore crosses
    nothing to the host."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.scheduler import (
        Scheduler, SchedulerConfig, StepClock, run_open_loop,
        synth_shared_prefix_traffic)

    arch = get_config(arch_name).reduced()
    params = init_params(jax.random.PRNGKey(0), arch)
    eng = InstrumentedEngine(
        arch, params, ServeConfig(batch_slots=2, max_ctx=64,
                                  prefix_cache_bytes=1 << 24))
    clock = StepClock()
    sched = Scheduler(eng, SchedulerConfig(prefill_token_budget=8),
                      clock=clock.now)
    traffic = synth_shared_prefix_traffic(
        n_requests, 0.5, seed=0, vocab_size=arch.vocab_size,
        n_prefixes=2, prefix_len=16, user_len=(3, 10), out_len=(2, 6))
    run_open_loop(sched, traffic, tick=clock.tick)
    report = eng.check()
    pc = eng.prefix_cache
    if pc.stats["hits"] < 1:
        raise InvariantViolation(
            f"{arch_name}: hit-heavy trace produced no prefix hits "
            f"({pc.stats}) — the drive is not exercising the cache")
    done = [r for r in sched.finished if r.finish_reason != "rejected"]
    if len(done) != n_requests:
        raise InvariantViolation(
            f"{arch_name}: {len(done)}/{n_requests} requests completed "
            "under the prefix-cache scheduler")
    want = sched.stats["admitted"] + eng.steps_checked
    if eng.fetches != want:
        raise InvariantViolation(
            f"{arch_name}: {eng.fetches} fetches for "
            f"{sched.stats['admitted']} admissions + {eng.steps_checked} "
            f"decode steps (expected {want}) — prefix adoption/insertion "
            "must stay device-side")
    report["completed"] = len(done)
    report["prefix_hits"] = pc.stats["hits"]
    report["prefix_misses"] = pc.stats["misses"]
    report["prefix_inserts"] = pc.stats["inserts"]
    report["prefill_tokens_saved"] = eng.stats["prefix_hit_tokens"]
    return report


def run_prefix_invariants(configs=INVARIANT_CONFIGS) -> dict:
    """Prefix-cache invariant run (see ``_drive_prefix``): compile
    budget and one-transfer rule re-proven under a hit-heavy trace;
    same report shape as ``run_invariants``."""
    out: Dict[str, dict] = {}
    failures: List[str] = []
    for name in configs:
        try:
            out[name] = _drive_prefix(name)
        except InvariantViolation as e:   # keep auditing the rest
            out[name] = {"error": str(e)}
            failures.append(name)
    return {"configs": out, "violations": len(failures),
            "failed": failures}


def _drive_spec(arch_name: str, n_requests: int = 5) -> dict:
    """Three speculative-decode scripts over instrumented engines.

    (a) Scheduler-driven greedy self-speculation over seeded traffic:
    the self drafter shares the decode jit key, every greedy verify /
    repair chunk passes the in-place compiled-bucket check (zero new
    prefill executables beyond admission's own), and the transfer
    ledger closes: one fetch per admission, per sequential fallthrough
    step, per draft dispatch and per verify dispatch — repair adds
    none.

    (b) Forced-mismatch drive: a ``draft_fn`` that is always wrong, so
    every iteration accepts exactly one token and (on archs with
    rollback-sensitive state — local rings, RG-LRU, SSM) triggers
    restore + repair. Proves the repair dispatch is fetch-free and that
    global-attention archs skip it entirely.

    (c) Sampled drive: rejection-rule verification compiles exactly one
    ``verify[bucket]`` executable — the only compile speculation is
    allowed beyond the drafter's own — traced once across steps."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.params import SamplingParams
    from repro.serving.scheduler import (
        Scheduler, SchedulerConfig, StepClock, run_open_loop, synth_traffic)
    from repro.serving.speculative import SpecConfig, SpecDecoder

    arch = get_config(arch_name).reduced()
    params = init_params(jax.random.PRNGKey(0), arch)

    # --- (a) scheduler traffic, greedy self-draft speculation
    eng = InstrumentedEngine(
        arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    clock = StepClock()
    sched = Scheduler(eng, SchedulerConfig(prefill_token_budget=10),
                      clock=clock.now, spec=SpecConfig(k=4, draft="self"))
    traffic = synth_traffic(n_requests, 0.5, seed=0,
                            vocab_size=arch.vocab_size,
                            prompt_len=(3, 14), out_len=(2, 6))
    run_open_loop(sched, traffic, tick=clock.tick)
    report = eng.check()
    st = eng.stats
    if st["spec_steps"] < 1 or st["spec_tokens"] <= st["spec_steps"]:
        raise InvariantViolation(
            f"{arch_name}: spec drive is not speculating (spec_steps="
            f"{st['spec_steps']}, spec_tokens={st['spec_tokens']})")
    extra = [k for k in eng.trace_counts
             if not (k.startswith("decode[") or k.startswith("prefill["))]
    if extra:
        raise InvariantViolation(
            f"{arch_name}: self-draft speculation compiled executables of "
            f"its own: {extra} — the self drafter must reuse the decode "
            "executable and greedy verify the admission prefill buckets")
    done = [r for r in sched.finished if r.finish_reason != "rejected"]
    if len(done) != n_requests:
        raise InvariantViolation(
            f"{arch_name}: {len(done)}/{n_requests} requests completed "
            "under the speculative scheduler")
    want = (sched.stats["admitted"] + eng.steps_checked
            + st["draft_dispatches"] + st["verify_dispatches"])
    if eng.fetches != want:
        raise InvariantViolation(
            f"{arch_name}: {eng.fetches} fetches for "
            f"{sched.stats['admitted']} admissions + {eng.steps_checked} "
            f"sequential steps + {st['draft_dispatches']} drafts + "
            f"{st['verify_dispatches']} verifies (expected {want}) — "
            "repair and restore must cross nothing to the host")
    report["completed"] = len(done)
    report["spec_steps"] = st["spec_steps"]
    report["spec_tokens"] = st["spec_tokens"]
    report["draft_dispatches"] = st["draft_dispatches"]
    report["verify_dispatches"] = st["verify_dispatches"]
    report["repair_dispatches"] = st["repair_dispatches"]

    # --- (b) always-wrong drafter: rollback + fetch-free repair
    eng2 = InstrumentedEngine(
        arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    dec2 = SpecDecoder(eng2, SpecConfig(k=4, draft="self"),
                       draft_fn=lambda cur, t: (cur + 1) % arch.vocab_size)
    s2 = eng2.add_request([3, 1, 4, 1, 5],
                          params=SamplingParams(max_tokens=6))
    while eng2.active[s2]:
        dec2.step()
    eng2.check()
    st2 = eng2.stats
    if st2["spec_tokens"] != st2["spec_steps"]:
        raise InvariantViolation(
            f"{arch_name}: an always-wrong drafter accepted "
            f"{st2['spec_tokens']} tokens over {st2['spec_steps']} steps "
            "(greedy acceptance must keep exactly the correction token)")
    needs_rollback = bool(eng2.spec_snapshot())
    if needs_rollback != (st2["repair_dispatches"] > 0):
        raise InvariantViolation(
            f"{arch_name}: {st2['repair_dispatches']} repair dispatches "
            f"but rollback-sensitive state present={needs_rollback} — "
            "recurrent/ring archs must repair on partial acceptance and "
            "pure global-attention archs must never")
    want2 = 1 + eng2.steps_checked + st2["verify_dispatches"]
    if eng2.fetches != want2:
        raise InvariantViolation(
            f"{arch_name}: forced-mismatch drive fetched {eng2.fetches} "
            f"(expected {want2}: 1 admission + {eng2.steps_checked} "
            f"sequential steps + {st2['verify_dispatches']} verifies; "
            "draft_fn drafts and repair dispatches fetch nothing)")
    report["forced_mismatch"] = {
        "repair_dispatches": st2["repair_dispatches"],
        "needs_rollback": needs_rollback,
    }

    # --- (c) sampled verification: one verify executable, traced once
    eng3 = InstrumentedEngine(
        arch, params, ServeConfig(batch_slots=2, max_ctx=64))
    dec3 = SpecDecoder(eng3, SpecConfig(k=4, draft="self"))
    eng3.add_request([2, 7, 1], params=SamplingParams(
        temperature=0.7, seed=3, max_tokens=32))
    for i in range(3):
        dec3.step(jax.random.PRNGKey(i))
    eng3.check()
    n_verify = sum(1 for k in eng3.trace_counts
                   if k.startswith("verify["))
    if n_verify != 1:
        raise InvariantViolation(
            f"{arch_name}: sampled speculation traced {n_verify} verify "
            f"executables (expected exactly 1): "
            f"{dict(eng3.trace_counts)}")
    st3 = eng3.stats
    want3 = (1 + eng3.steps_checked + st3["draft_dispatches"]
             + st3["verify_dispatches"])
    if eng3.fetches != want3:
        raise InvariantViolation(
            f"{arch_name}: sampled drive fetched {eng3.fetches} "
            f"(expected {want3}) — the packed verify result must be the "
            "dispatch's single fetch")
    # structural counts only: sampled *acceptance* depends on platform
    # float numerics, so it must stay out of the exact-gated golden
    report["sampled"] = {
        "verify_executables": n_verify,
        "draft_dispatches": st3["draft_dispatches"],
        "verify_dispatches": st3["verify_dispatches"],
    }
    return report


def run_spec_invariants(configs=INVARIANT_CONFIGS) -> dict:
    """Speculative-decode invariant run (see ``_drive_spec``): compile
    budget (verify/repair reuse admission bucket executables; the self
    drafter reuses the decode executable; sampled verify adds exactly
    one), one-transfer rule with fetch-free repair, and per-family
    rollback behaviour; same report shape as ``run_invariants``."""
    out: Dict[str, dict] = {}
    failures: List[str] = []
    for name in configs:
        try:
            out[name] = _drive_spec(name)
        except InvariantViolation as e:   # keep auditing the rest
            out[name] = {"error": str(e)}
            failures.append(name)
    return {"configs": out, "violations": len(failures),
            "failed": failures}
