"""Numerics sanitizer sink for the GR-MAC kernel backends.

``REPRO_SANITIZE=1`` (read per call in ``kernels.dispatch._run_plan``)
makes the xla/tiled/ref backends stage three in-graph checks around the
pre-ADC compute-line voltage of every block:

``nonfinite``
    any NaN/Inf in the voltage ``v`` entering ``adc_quantize`` — the
    canonical symptom of a zero/denormal denominator or an upstream blowup
    that would otherwise surface as downstream loss corruption;
``adc_overflow``
    ``|v| > 1`` (beyond float slack): the local-normalization contract
    guarantees the compute line stays inside the ADC full-scale range, so
    an overflow means the gain-ranging path did *not* cover the operand
    distribution it claimed (the AFPR-CIM failure mode);
``gain_range``
    the per-block exponent span ``max(E) - min(E)`` (row granularity;
    ``E(x)+E(w)`` per column for unit) exceeding
    ``core.dse.GAIN_RANGE_LIMIT_BITS`` — the C-2C coupling-ladder depth the
    DSE treats as a hard feasibility wall. The static mirror of this check
    is ``CimDesign.gain_range_bits``; this one sees the *actual* operands,
    so formats that are statically feasible but driven with out-of-family
    data still get caught.

Checks report through ``jax.debug.callback`` into the module-level
``VIOLATIONS`` list (and a stderr line), so they work inside ``jit`` and
name the offending site via the ``tag`` threaded down from
``ops.cim_matmul``. When the flag is unset the backends receive
``sanitize=False`` and stage **zero** extra primitives — the checks are
structurally absent from the jaxpr, not merely disabled.

Usage::

    REPRO_SANITIZE=1 python ...            # or monkeypatch.setenv in tests
    from repro.analysis import sanitize
    sanitize.clear()
    ... run model / kernels ...
    assert not sanitize.VIOLATIONS, sanitize.VIOLATIONS
"""
from __future__ import annotations

import os
import sys
from typing import List

import jax
import jax.numpy as jnp

from repro.core.dse import GAIN_RANGE_LIMIT_BITS

__all__ = [
    "ENV_VAR",
    "OVERFLOW_TOL",
    "VIOLATIONS",
    "enabled",
    "clear",
    "check_values",
    "check_gain_span",
]

ENV_VAR = "REPRO_SANITIZE"

# |v| may legitimately graze 1.0 (full-scale inputs) and float renorm can
# overshoot by a few ulp; anything past this slack is a real range escape.
OVERFLOW_TOL = 1.0 + 1e-5

# Violation records: {"kind", "tag", "count", "worst"} dicts, appended in
# execution order. Host-side state — clear() between runs.
VIOLATIONS: List[dict] = []


def enabled() -> bool:
    """Whether ``REPRO_SANITIZE`` currently requests instrumentation."""
    return os.environ.get(ENV_VAR, "0") not in ("", "0")


def clear() -> None:
    VIOLATIONS.clear()


def _record(kind: str, tag: str, count, worst) -> None:
    count = int(count)
    if not count:
        return
    rec = {"kind": kind, "tag": str(tag) or "<untagged>",
           "count": count, "worst": float(worst)}
    VIOLATIONS.append(rec)
    print(f"[repro.sanitize] {rec['kind']} at {rec['tag']}: "
          f"count={rec['count']} worst={rec['worst']:g}", file=sys.stderr)


def check_values(tag: str, v: jax.Array) -> None:
    """Stage nonfinite + pre-ADC overflow checks on compute-line ``v``."""
    finite = jnp.isfinite(v)
    nonfin = jnp.size(v) - jnp.sum(finite)
    jax.debug.callback(_record, "nonfinite", tag, nonfin, jnp.inf)
    mag = jnp.abs(jnp.where(finite, v, 0.0))
    worst = jnp.max(mag) if v.size else jnp.float32(0.0)
    over = jnp.sum(mag > OVERFLOW_TOL)
    jax.debug.callback(_record, "adc_overflow", tag, over, worst)


def check_gain_span(tag: str, span_bits: jax.Array,
                    limit: int = GAIN_RANGE_LIMIT_BITS) -> None:
    """Stage the gain-range-limit check on per-block exponent spans."""
    worst = jnp.max(span_bits) if span_bits.size else jnp.int32(0)
    count = jnp.sum(span_bits > limit)
    jax.debug.callback(_record, "gain_range", tag, count, worst)
