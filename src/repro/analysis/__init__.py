"""Static-analysis subsystem: machine-checked guarantees for the ledger.

Design note
-----------
Everything downstream of PR 4/5 — the CostLedger, the per-site Pareto
DSE, the deployment fronts — derives energy from shape-only traces that
*trust* model authors to route every projection through ``cim_matmul``
with a valid site label. This package converts that convention into
three machine-checked proofs, one module per pass:

``jaxpr_audit``  (run per config × {prefill, decode, train})
    Walks the closed jaxpr of the exact functions the ledger traces
    (``core.costs.phase_trace_spec``) and proves every ``dot_general`` /
    ``conv`` primitive is attributable: tagged with a
    ``cim_<site>_m<M>_k<K>_n<N>`` marker whose non-transpose
    ``cim_values`` count matches the CostLedger entry exactly, or
    declared digital via a ``dig_*`` scope. Reports untagged MACs with
    source locations, count mismatches, and f32 promotions inside the
    ``REPRO_GRMAC_BF16_VALUES`` regime.

``invariants``
    ``InstrumentedEngine`` wraps the serving engine's dedicated seams
    (``_compiled_decode``/``_compiled_prefill``/``_fetch``) and enforces
    at most one compile per (arch, bucket)/(arch, sample) executable and
    exactly one device→host transfer per decode step.

``sanitize``
    The opt-in (``REPRO_SANITIZE=1``) numerics sanitizer sink: the
    xla/tiled/ref GR-MAC backends stage in-graph NaN/Inf, pre-ADC
    overflow, and gain-range-limit checks that report per call site via
    ``jax.debug.callback``; structurally zero-cost when unset.

Report schema (``python -m repro.analysis --out ...``)::

    {"schema": 1, "phases": [...], "failures": N,
     "configs": {"<name>": {"failures": N, "phases": {"<phase>": {
         dot_generals, convs, tagged_values, tagged_gains,
         declared_digital, transposes, untagged, untagged_details[],
         ledger_mismatches, ledger_mismatch_details[], dtype_f32,
         dtype_bf16, dtype_flags[], calls, macs,
         contracts: {"<site>_m<M>_k<K>_n<N>": {ledger, traced}}}},
         "bf16_regime": {... decode re-audit under bf16 values ...}}},
     "invariants": {"violations": N, "configs": {"<name>": {
         traces{}, compiles, fetches, steps}}}}

Run locally::

    PYTHONPATH=src python -m repro.analysis                  # paper config
    PYTHONPATH=src python -m repro.analysis --all-configs \
        --out experiments/audit/audit_report.json            # the CI lane

The committed golden lives at ``experiments/audit/audit_report.json``
and is gated by exact-equality diff in ``benchmarks/compare.py``
(``--bench audit``), so any change in ledger coverage shows up as a
diff, not a silent drift.

Imports are lazy (``__getattr__``): the kernels import ``sanitize``
from inside traced bodies, and an eager package import would cycle
through models → kernels → analysis.
"""
from __future__ import annotations

import importlib

__all__ = ["jaxpr_audit", "invariants", "sanitize", "cli"]


def __getattr__(name):
    if name in __all__:
        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
