"""Jaxpr ledger audit: prove the CostLedger covers every MAC.

The energy bound the paper derives is only as good as the MAC accounting
under it — any ``dot_general``/``conv`` that escapes the ledger silently
breaks the bound. This pass traces the *same* functions the ledger traces
(``core.costs.phase_trace_spec``: prefill / decode / train-grad per arch),
walks the closed jaxpr — including ``scan``/``cond``/``pjit``/``custom_vjp``
sub-jaxprs — and classifies every MAC primitive by the ``jax.named_scope``
markers the kernels and models stamp:

``cim_<site>_m<M>_k<K>_n<N>``
    one ``cim_matmul`` ledger contract (``kernels.ops.site_marker``); the
    nested ``cim_values`` scope marks the contraction that realizes it
    (``cim_gains`` the unit-normalization denominator). The audit counts
    non-transpose ``cim_values`` primitives per marker and cross-checks
    the count against the ledger entry exactly.
``dig_*`` (``dig_attn``, ``dig_ssm_ssd``, ``dig_ste_bwd``)
    contractions that are digital *by design* (attention scores, SSD dual
    form, the STE backward) — declared, so their absence from the ledger
    is proven intentional rather than assumed.
anything else
    an **untagged MAC** — a ledger leak, reported with the primitive's
    user source location.

Name-stack semantics (verified on jax 0.4.37): sub-jaxpr bodies reset
``eqn.source_info.name_stack``, but the call eqn carries the enclosing
scopes, so the walker prefix-accumulates stacks when it recurses. Under
``grad``, transposed applications carry ``transpose(...)`` in the stack
and are excluded from forward counts (the ledger records forward
contracts only; the STE backward is explicitly digital).

The audit forces a deterministic kernel regime during tracing
(``REPRO_GRMAC_BACKEND=xla`` so no ``pallas_call`` hides its dots,
sanitize/bf16 off); ``bf16_values_regime=True`` re-traces under
``REPRO_GRMAC_BF16_VALUES=1`` and flags any f32 values contraction at a
site whose formats admit exact bf16 products (an unexpected dtype
promotion in the fast-GEMM regime).
"""
from __future__ import annotations

import contextlib
import os
import re
from typing import Dict, List, Optional, Tuple

import jax

from repro.core import costs
from repro.core.cim_config import SITES

__all__ = [
    "MARKER_RE",
    "MAC_PRIMITIVES",
    "iter_eqns",
    "audit_phase",
    "audit_arch",
]

# Anchored on "_m<digits>_k<digits>_n<digits>": site names themselves
# contain underscores (attn_qkv, moe_expert) but never that suffix shape.
MARKER_RE = re.compile(r"cim_(?P<site>\w+?)_m(?P<m>\d+)_k(?P<k>\d+)_n(?P<n>\d+)")
DIG_RE = re.compile(r"dig_\w+")

MAC_PRIMITIVES = ("dot_general", "conv_general_dilated")

# Primitives that could swallow MAC primitives where the walker cannot see
# them. The audit regime forces the xla backend so none should appear; if
# one does, it is reported as opaque rather than silently passed.
_OPAQUE = ("pallas_call",)


def _jaxpr_types():
    from jax._src import core as _core
    return _core.Jaxpr, _core.ClosedJaxpr


def _sub_jaxprs(params: dict):
    jaxpr_t, closed_t = _jaxpr_types()
    for v in params.values():
        items = v if isinstance(v, (list, tuple)) else (v,)
        for x in items:
            if isinstance(x, closed_t):
                yield x.jaxpr
            elif isinstance(x, jaxpr_t):
                yield x


def iter_eqns(jaxpr, prefix: str = ""):
    """Yield ``(eqn, effective_name_stack)`` over a jaxpr and all its
    sub-jaxprs. Sub-jaxpr traces reset the name stack, so the effective
    stack prefixes the enclosing call eqns' stacks onto each eqn's own."""
    for eqn in jaxpr.eqns:
        own = str(eqn.source_info.name_stack)
        eff = f"{prefix}/{own}" if prefix and own else (prefix or own)
        yield eqn, eff
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub, eff)


def _source_of(eqn) -> Tuple[Optional[str], Optional[int]]:
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return frame.file_name, int(frame.start_line)
    except Exception:
        pass
    return None, None


@contextlib.contextmanager
def _audit_env(bf16: bool):
    """Pin the kernel regime for a trace: concrete xla backend (Pallas
    hides its dots inside ``pallas_call``), sanitizer off (no
    ``debug_callback`` noise in the golden), bf16 values as requested."""
    keys = {"REPRO_GRMAC_BACKEND": "xla",
            "REPRO_SANITIZE": "0",
            "REPRO_GRMAC_BF16_VALUES": "1" if bf16 else "0"}
    saved = {k: os.environ.get(k) for k in keys}
    os.environ.update(keys)
    try:
        yield
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _ledger_contracts(ledger: costs.CostLedger) -> Dict[str, dict]:
    """Collapse ledger entries to marker-keyed contract records."""
    out: Dict[str, dict] = {}
    for entry, count in ledger.entries():
        key = f"{entry.site}_m{entry.m}_k{entry.k}_n{entry.n}"
        rec = out.setdefault(key, {
            "site": entry.site, "ledger": 0,
            "fmt_x": entry.fmt_x.name, "fmt_w": entry.fmt_w.name,
            "mode": entry.mode, "granularity": entry.granularity,
        })
        rec["ledger"] += count
    return out


def _bf16_exact(fmt_x_name: str, fmt_w_name: str) -> bool:
    from repro.core import formats
    from repro.kernels.xla import bf16_products_exact
    fx = getattr(formats, fmt_x_name, None)
    fw = getattr(formats, fmt_w_name, None)
    return (fx is not None and fw is not None
            and bf16_products_exact(fx, fw))


def audit_phase(arch, phase: str, *,
                bf16_values_regime: bool = False) -> dict:
    """Audit one (arch, phase): trace, walk, classify, cross-check.

    Returns a JSON-able dict; ``untagged == 0`` and
    ``ledger_mismatches == 0`` are the pass conditions.
    """
    fn, args = costs.phase_trace_spec(arch, phase)
    ledger = costs.CostLedger()
    with _audit_env(bf16_values_regime):
        with costs.recording(ledger):
            closed = jax.make_jaxpr(fn)(*args)

    contracts = _ledger_contracts(ledger)
    for rec in contracts.values():
        rec["traced"] = 0

    n_dot = n_conv = 0
    tagged_values = tagged_gains = tagged_other = 0
    declared_digital = transposes = 0
    dtype_f32 = dtype_bf16 = 0
    untagged: List[dict] = []
    unknown_sites: List[dict] = []
    dtype_flags: List[dict] = []
    opaque: List[dict] = []

    for eqn, stack in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if name in _OPAQUE:
            fname, line = _source_of(eqn)
            opaque.append({"primitive": name, "stack": stack,
                           "file": fname, "line": line})
            continue
        if name not in MAC_PRIMITIVES:
            continue
        if name == "dot_general":
            n_dot += 1
        else:
            n_conv += 1
        if "transpose(" in stack:
            # backward transpose of a forward contraction: the forward
            # instance is what the ledger counts; an untagged transpose
            # implies an untagged forward, already reported there.
            transposes += 1
            continue
        marker = None
        for m in MARKER_RE.finditer(stack):
            marker = m           # innermost (rightmost) marker wins
        if marker is not None:
            key = marker.group(0)[len("cim_"):]
            site = marker.group("site")
            rec = contracts.get(key)
            if site not in SITES or rec is None:
                fname, line = _source_of(eqn)
                unknown_sites.append(
                    {"marker": marker.group(0), "stack": stack,
                     "file": fname, "line": line,
                     "reason": ("site not in SITES" if site not in SITES
                                else "no matching ledger contract")})
                continue
            if "cim_values" in stack:
                tagged_values += 1
                rec["traced"] += 1
                op = eqn.invars[0].aval.dtype
                if str(op) == "bfloat16":
                    dtype_bf16 += 1
                else:
                    dtype_f32 += 1
                    if (bf16_values_regime and rec["mode"] == "grmac"
                            and _bf16_exact(rec["fmt_x"], rec["fmt_w"])):
                        fname, line = _source_of(eqn)
                        dtype_flags.append(
                            {"marker": marker.group(0), "dtype": str(op),
                             "file": fname, "line": line,
                             "reason": "f32 values contraction in the "
                                       "bf16-values regime"})
            elif "cim_gains" in stack:
                tagged_gains += 1
            else:
                # under a site marker but neither values nor gains: still
                # attributable (e.g. helper contractions a future backend
                # adds), counted separately so the golden surfaces them
                tagged_other += 1
            continue
        if DIG_RE.search(stack):
            declared_digital += 1
            continue
        fname, line = _source_of(eqn)
        untagged.append({"primitive": name, "stack": stack,
                         "file": fname, "line": line})

    mismatches = [
        {"contract": key, "ledger": rec["ledger"], "traced": rec["traced"]}
        for key, rec in sorted(contracts.items())
        if rec["ledger"] != rec["traced"]
    ]

    return {
        "phase": phase,
        "dot_generals": n_dot,
        "convs": n_conv,
        "tagged_values": tagged_values,
        "tagged_gains": tagged_gains,
        "tagged_other": tagged_other,
        "declared_digital": declared_digital,
        "transposes": transposes,
        "untagged": len(untagged),
        "untagged_details": untagged,
        "unknown_site_details": unknown_sites,
        "opaque_details": opaque,
        "ledger_mismatches": len(mismatches) + len(unknown_sites)
        + len(opaque),
        "ledger_mismatch_details": mismatches,
        "dtype_f32": dtype_f32,
        "dtype_bf16": dtype_bf16,
        "dtype_flags": dtype_flags,
        "calls": sum(r["ledger"] for r in contracts.values()),
        "macs": ledger.macs(),
        "contracts": {
            key: {"ledger": rec["ledger"], "traced": rec["traced"]}
            for key, rec in sorted(contracts.items())
        },
    }


def _runs_grmac(arch) -> bool:
    if not arch.cim.enabled:
        return False
    designs = [arch.cim.for_site(s) for s in SITES]
    return any(d is not None and d.enabled and d.mode == "grmac"
               for d in designs)


def audit_arch(arch, phases=("prefill", "decode", "train"), *,
               bf16_regime_check: bool = True) -> dict:
    """Audit every phase of one arch. When the arch runs grmac anywhere
    and ``bf16_regime_check`` is set, the decode phase is additionally
    re-audited under ``REPRO_GRMAC_BF16_VALUES=1`` to catch f32 dtype
    promotions inside the bf16 values path."""
    out = {"phases": {p: audit_phase(arch, p) for p in phases}}
    if bf16_regime_check and _runs_grmac(arch) and "decode" in phases:
        out["bf16_regime"] = audit_phase(arch, "decode",
                                         bf16_values_regime=True)
    checked = list(out["phases"].values())
    if "bf16_regime" in out:
        checked.append(out["bf16_regime"])
    out["failures"] = sum(ph["untagged"] + ph["ledger_mismatches"]
                          + len(ph["dtype_flags"]) for ph in checked)
    return out
