"""Deterministic, shardable, *resumable* token pipeline.

Every batch is a pure function of (seed, step), so a restarted job resumes
bit-identically from its checkpointed step with no data-state file — the
property fault-tolerant training needs most from the input side.  Two
sources:

* SyntheticLM — a fixed-seed Zipf-ish token stream (benchmarks, dry-runs,
  smoke tests);
* FileTokens  — memory-mapped flat token file (real corpora), strided so
  each (step, host) pair reads a disjoint window.

Batches carry ``inputs``/``labels`` shifted by one, plus a loss mask.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "FileTokens", "make_pipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | file
    path: Optional[str] = None
    embedding_dim: int = 0             # >0 -> emit embeddings (modality stub)


class SyntheticLM:
    """Zipf-distributed tokens with a deterministic (seed, step) mapping."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        shape = (cfg.global_batch, cfg.seq_len + 1)
        # Zipf via exponentiated uniform — cheap, heavy-tailed like text.
        u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0)
        toks = jnp.minimum(
            (u ** (-0.7) - 1.0).astype(jnp.int32), cfg.vocab_size - 1)
        batch = {
            "labels": toks[:, 1:],
            "mask": jnp.ones((cfg.global_batch, cfg.seq_len), jnp.float32),
        }
        if cfg.embedding_dim:
            kemb = jax.random.fold_in(key, 1)
            batch["inputs"] = jax.random.normal(
                kemb, (cfg.global_batch, cfg.seq_len, cfg.embedding_dim),
                jnp.float32)
        else:
            batch["inputs"] = toks[:, :-1]
        return batch


class FileTokens:
    """Flat uint16/uint32 token file, strided deterministically by step."""

    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        assert cfg.path, "FileTokens needs cfg.path"
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        n = cfg.global_batch
        span = cfg.seq_len + 1
        total = len(self.data) - span
        rng = np.random.default_rng(cfg.seed + step)
        starts = rng.integers(0, total, size=n)
        toks = np.stack([self.data[s : s + span] for s in starts]).astype(np.int32)
        toks = np.minimum(toks, cfg.vocab_size - 1)
        return {
            "inputs": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "mask": jnp.ones((n, cfg.seq_len), jnp.float32),
        }


def make_pipeline(cfg: DataConfig):
    return FileTokens(cfg) if cfg.source == "file" else SyntheticLM(cfg)


def iterate(pipeline, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield pipeline.batch_at(step)
        step += 1
