from repro.data.pipeline import DataConfig, FileTokens, SyntheticLM, make_pipeline
__all__ = ["DataConfig", "SyntheticLM", "FileTokens", "make_pipeline"]
