from repro.parallel.sharding import (
    batch_axes,
    get_mesh,
    named_sharding_tree,
    param_specs,
    set_mesh,
    shard,
    use_mesh,
)

__all__ = [
    "set_mesh", "get_mesh", "use_mesh", "shard", "batch_axes",
    "param_specs", "named_sharding_tree",
]
