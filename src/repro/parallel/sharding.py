"""Mesh-aware sharding helpers and parameter partition rules.

The production mesh axes are ("pod", "data", "model") (multi-pod) or
("data", "model") (single pod).  Parameters are 2D-sharded — FSDP over the
("pod","data") axes and tensor-parallel over "model" — with *best-effort*
divisibility: a dim is only sharded if its size divides the axis size, so one
rule set serves all ten architectures (vocab 151936 is not 256-divisible,
expert counts differ, etc.). GSPMD propagates the rest.

``set_mesh``/``shard`` give layers a way to drop activation sharding
constraints without threading the mesh through every call (no-op when no
mesh is active — smoke tests and benches run un-meshed).
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "set_mesh",
    "get_mesh",
    "use_mesh",
    "shard",
    "batch_axes",
    "axis_divides",
    "param_specs",
    "named_sharding_tree",
]

_ACTIVE_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


@contextmanager
def use_mesh(mesh: Mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def batch_axes(mesh: Optional[Mesh] = None):
    """Mesh axes that shard the batch dim: ("pod","data") when present."""
    mesh = mesh or _ACTIVE_MESH
    if mesh is None:
        return None
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes or None


def axis_divides(n: int, axis: str) -> bool:
    """True when dim size ``n`` divides the active mesh's ``axis`` size
    (True with no active mesh — constraints are no-ops then anyway)."""
    mesh = _ACTIVE_MESH
    if mesh is None or axis not in mesh.axis_names:
        return True
    return n % mesh.shape[axis] == 0


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def shard(x: jax.Array, *spec) -> jax.Array:
    """Best-effort with_sharding_constraint; no-op without an active mesh.

    ``spec`` entries: "data" expands to the batch axes; "all" to every mesh
    axis (batch + model — e.g. attention activations whose head count does
    not divide the model axis get their *batch* spread over all chips);
    "model"; None. Entries whose dim size is not divisible by the axis size
    are dropped.
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    resolved = []
    for dim, ax in zip(x.shape, spec):
        if ax == "data":
            ax = batch_axes(mesh)
        elif ax == "all":
            ax = tuple(mesh.axis_names)
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None
        resolved.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved))
    )


# ------------------------------------------------------------------ params
# Rules: (path regex, preferred spec per dim). "model" = TP axis,
# "fsdp" = the ("pod","data") product. Dims that don't divide fall back
# to replication for that dim.
_RULES = [
    (r"embed", ("model", "fsdp")),
    (r"lm_head", ("fsdp", "model")),
    (r"router", (None, "model")),
    # MoE experts: (E, D, F) — expert dim over model when divisible,
    # else F over model (intra-expert TP).
    (r"experts.*w[ig]$", ("model", "fsdp", None)),
    (r"experts.*wo$", ("model", None, "fsdp")),
    (r"\bwq\b|\bwk\b|\bwv\b|\bwi\b|\bwg\b", ("fsdp", "model")),
    (r"\bwo\b", ("model", "fsdp")),
    # ssm / rglru projections
    (r"in_proj|x_proj|gate", ("fsdp", "model")),
    (r"out_proj", ("model", "fsdp")),
    (r"conv", (None, None, None)),
]


def _spec_for(path: str, shape, mesh: Mesh, use_fsdp: bool = True) -> P:
    fsdp = batch_axes(mesh) if use_fsdp else None
    # MoE expert weights need a fallback: EP over "model" when the expert
    # count divides it, else intra-expert TP on the hidden dim (e.g. grok's
    # 8 experts at 16-way TP).
    if re.search(r"experts", path) and len(shape) >= 3:
        e = shape[-3]
        if e % _axis_size(mesh, "model") == 0:
            pref = (("model", "fsdp", None) if path.endswith(("wi", "wg"))
                    else ("model", None, "fsdp"))
        else:
            pref = ((None, "fsdp", "model") if path.endswith(("wi", "wg"))
                    else (None, "model", "fsdp"))
        return _align(pref, shape, mesh, fsdp)
    for pat, pref in _RULES:
        if re.search(pat, path):
            return _align(pref, shape, mesh, fsdp)
    return P()  # replicate (norms, biases, scalars)


def _align(pref, shape, mesh, fsdp) -> P:
    # Right-align prefs to the trailing dims: scanned super-block params
    # carry a leading (n_layers/period) stacking dim that must stay
    # unsharded.
    pad = max(0, len(shape) - len(pref))
    aligned = (None,) * pad + tuple(pref[-len(shape):])
    spec = []
    used = set()
    for dim, ax in zip(shape, aligned):
        ax = fsdp if ax == "fsdp" else ax
        key = tuple(ax) if isinstance(ax, (tuple, list)) else ax
        if ax is None or key in used or dim % _axis_size(mesh, ax) != 0:
            spec.append(None)
        else:
            used.add(key)
            spec.append(ax)
    return P(*spec)


def param_specs(params_shape, mesh: Mesh, use_fsdp: bool = True):
    """PartitionSpec pytree for a (possibly abstract) params pytree.

    ``use_fsdp=False`` gives the ZeRO-1 layout: tensors keep only their
    "model" (TP) sharding and are replicated over the data axes — pair it
    with FSDP-sharded optimizer state to trade param replication for the
    elimination of per-step weight all-gathers.
    """
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]

    def name(path):
        return "/".join(str(getattr(k, "key", k)) for k in path)

    specs = {name(p): _spec_for(name(p), v.shape, mesh, use_fsdp)
             for p, v in flat}

    def mapper(path, v):
        return specs[name(path)]

    return jax.tree_util.tree_map_with_path(mapper, params_shape)


def named_sharding_tree(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
