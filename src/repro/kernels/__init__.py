"""GR-MAC kernel subsystem: one op, many backends, shape-aware planning.

The paper's core artifact — the gain-ranged MAC matmul — is exposed as a
single dispatch surface with interchangeable, cross-validated execution
backends:

    ops.cim_matmul        model-facing op (pre-scale, mode switch, STE
                          gradients); what ``models.layers`` calls
    dispatch.grmac_matmul plan-based backend selection + shape padding
    xla.py                fully-vectorized batched-einsum backend — fastest
                          at small M (decode shapes) on CPU/GPU
    tiled.py              fused M(xN)-tiled backend (``lax.scan`` tiles,
                          den/ADC/renorm epilogue inside the tile body) —
                          the large-M (training-shape) winner off-TPU
    grmac_matmul.py       Pallas TPU kernel (default on TPU); its
                          interpret mode is kept as an explicit debug
                          backend ("pallas_interpret")
    ref.py                readable pure-jnp oracle ("ref")

Site threading & cost accounting
--------------------------------
``cim_matmul`` takes a ``site=`` label naming the model call site that
issued the matmul (``core.cim_config.SITES``: ``attn_qkv`` / ``attn_o`` /
``mlp`` / ``moe_router`` / ``moe_expert`` / ``rglru`` / ``ssm`` /
``head``). The site does two jobs at this single choke point:

1. **policy** — ``CIMConfig.for_site(site)`` resolves which design (or
   "off") runs there: ``site_overrides`` first (first-class mixed
   deployments, e.g. a conv-granularity head next to a gr-row FFN), the
   legacy family-level ``apply_to`` otherwise;
2. **accounting** — when a ``core.costs.recording`` trace is active, the
   executed contract ``(site, M, K, N, granularity, fmt_x, fmt_w, n_r)``
   is recorded into the active ``CostLedger`` *before* dispatch, shapes
   read at Python level so a shape-only ``jax.eval_shape`` of the real
   model functions yields exact op counts (``core.costs.trace_decode`` /
   ``trace_prefill`` / ``trace_train``). Outside a trace the hook is one
   list check. ``serving.engine.energy_report`` prices those ledgers per
   site design — there is no separate analytic MAC census to drift.

Two call sites record logical rather than physical shapes: the MoE expert
stacks (``tokens × top_k`` routed rows, not the fixed-capacity dispatch
buffer — recorded explicitly in ``models.moe``) and the LM head (true
``vocab_size``, not the 256-padded matmul width, via ``logical_n=``).

Machine-checked invariants
--------------------------
The accounting contract above is proven, not trusted, by the static
analysis lane (``repro.analysis``, CI ``audit`` job):

1. **Ledger completeness** — every ``cim_matmul`` call wraps its
   realizing contraction in ``jax.named_scope`` markers
   (``ops.site_marker``: ``cim_<site>_m<M>_k<K>_n<N>`` around the call,
   ``cim_values`` on the values contraction, ``cim_gains`` on the unit
   denominator), and model-level digital contractions declare themselves
   (``dig_attn`` / ``dig_ssm_ssd`` / ``dig_ste_bwd``). The jaxpr audit
   walks the traced prefill/decode/train programs and fails on any
   ``dot_general``/``conv`` carrying none of these, and on any marker
   whose traced count disagrees with the CostLedger entry. When adding a
   contraction, either route it through ``cim_matmul`` or wrap it in a
   ``dig_*`` scope — an unlabeled one fails CI with its source location.
2. **Numerics sanitizer** — ``REPRO_SANITIZE=1`` (read per call in
   ``dispatch._run_plan``) makes the xla/tiled/ref backends stage
   in-graph NaN/Inf, pre-ADC overflow (|v| > 1), and gain-range-limit
   checks via ``jax.debug.callback`` into
   ``repro.analysis.sanitize.VIOLATIONS``; unset, the checks are
   structurally absent (zero extra jaxpr primitives, bit-identical
   outputs — asserted by tests/test_sanitize.py). Pallas backends are
   not instrumented (the kernel body is opaque to ``debug.callback``);
   cross-backend 0-ulp equality covers them indirectly.

Backend selection
-----------------
``CIMConfig.backend`` (or a ``backend=`` call override) names a backend or
"auto". "auto" resolves through ``dispatch.plan_for``: pallas on TPU;
off-TPU a ``Plan(backend, tile_m, tile_n)`` keyed on
``(M, K, N, granularity, formats, n_r, platform)`` — served from the
in-memory plan table, then the persisted JSON plan cache, then (with
autotuning enabled) a measure-once micro-probe, else the static heuristic
(``M >= 64`` -> tiled, smaller -> xla). ``CIMConfig.tile_m``/``tile_n``
(and ``grmac_matmul(tile_m=, tile_n=)``) pin tile sizes explicitly;
``ServeConfig.cim_backend``/``TrainConfig.cim_backend`` (+ their
``cim_tile_m``/``cim_tile_n``) override per call site.

Environment knobs
-----------------
``REPRO_GRMAC_BACKEND``      force a backend name for every "auto" call
                             (explicit ``backend=`` arguments still win).
``REPRO_GRMAC_AUTOTUNE=1``   enable the micro-autotune: unknown shapes are
                             probed once (candidate backends x tile sizes,
                             on synthetic operands), the winner is
                             persisted, and later calls — in this or any
                             other process — reuse it for free.
``REPRO_GRMAC_PLAN_CACHE``   path of the persisted plan JSON (default
                             ``~/.cache/repro/grmac_plans.json``).
``REPRO_SANITIZE=1``         stage the in-graph numerics sanitizer on the
                             xla/tiled/ref backends (see "Machine-checked
                             invariants" above); off by default and
                             structurally free when off.
``REPRO_GRMAC_BF16_VALUES=1``  run the values einsums of the xla/tiled
                             backends with bf16 operands + f32 accumulator
                             when the formats make every product exact
                             (silent f32 fallback otherwise; see
                             kernels/xla.py for the caveat on accelerators).

All backends implement the same semantics contract and are cross-checked
at 0-ulp tolerance in tests/test_kernels.py and tests/test_properties.py;
``benchmarks/kernel_bench.py --backend all`` compares their wall time and
``benchmarks/compare.py`` guards the committed numbers against regression.
"""
from repro.kernels.dispatch import (
    BACKENDS,
    Plan,
    grmac_matmul,
    plan_for,
    resolve_backend,
)
from repro.kernels.ops import cim_matmul

__all__ = ["BACKENDS", "Plan", "cim_matmul", "grmac_matmul", "plan_for",
           "resolve_backend"]
