"""GR-MAC kernel subsystem: one op, many backends.

The paper's core artifact — the gain-ranged MAC matmul — is exposed as a
single dispatch surface with interchangeable, cross-validated execution
backends:

    ops.cim_matmul        model-facing op (pre-scale, mode switch, STE
                          gradients); what ``models.layers`` calls
    dispatch.grmac_matmul backend selection + shape padding
    xla.py                fast fully-vectorized pure-XLA backend
                          (default on CPU/GPU)
    grmac_matmul.py       Pallas TPU kernel (default on TPU); its
                          interpret mode is kept as an explicit debug
                          backend ("pallas_interpret")
    ref.py                readable pure-jnp oracle ("ref")

Backend choice: ``CIMConfig.backend`` (or a ``backend=`` call override,
or the ``REPRO_GRMAC_BACKEND`` env var). All backends implement the same
semantics contract and are cross-checked in tests/test_kernels.py;
``benchmarks/kernel_bench.py --backend all`` compares their wall time.
"""
from repro.kernels.dispatch import BACKENDS, grmac_matmul, resolve_backend
from repro.kernels.ops import cim_matmul

__all__ = ["BACKENDS", "cim_matmul", "grmac_matmul", "resolve_backend"]
