"""Fast pure-XLA GR-MAC backend — the default off-TPU.

Implements the same semantics contract as ``ref.py`` / the Pallas kernel
(see ``ref.py`` for the math), but is written for throughput on CPU/GPU
rather than as a readable oracle or a TPU lowering:

* the K dimension is reshaped into ``(K / n_r, n_r)`` sub-blocks and every
  gain-ranged partial dot product runs as **one batched einsum per
  operand pair** — there is no Python loop over blocks and no 128-padding
  requirement (only ``K % n_r == 0``, handled by ``dispatch.py``);
  stacking the ``unit`` values/gain matmuls into a single 6-D contraction
  was measured *slower* than two plain batched GEMMs (XLA CPU lowers the
  extra stacking dim poorly), so unit runs two einsums;
* quantization / exponent extraction reuse the exact grid primitives from
  ``core.formats`` (frexp + ldexp), so the output is bit-identical to
  ``grmac_matmul_ref`` — the cross-backend tests assert equality at 0 ulp
  tolerance on every granularity.

The whole function is jit-compiled with static format/shape knobs and is
vmap- and grad-safe (pure ``jnp``; gradients follow the usual
straight-through convention of ``jnp.round``). Interpret-mode Pallas runs
the same shapes ~3 orders of magnitude slower; ``benchmarks/kernel_bench.py
--backend all`` measures the gap.

bf16 values-einsum variant (``bf16_values=True``, reached via
``REPRO_GRMAC_BF16_VALUES=1`` through ``dispatch.py``): the matmul operands
carry very few significant bits — quantized inputs have ``n_man_x + 1`` and
format-grid weights ``n_man_w + 1`` — so each elementwise *product* is
exactly representable in bfloat16's 8 significand bits whenever
``(n_man_x + 1) + (n_man_w + 1) <= 8`` (e.g. FP6_E3M2 × FP4_E2M1 = 5 bits).
The values/gains einsums then run with bf16 operands and
``preferred_element_type=float32``, which on MXU/tensor-core hardware hits
the fast mixed-precision GEMM path at zero rounding cost in the multiply.
Formats that don't satisfy the bound silently fall back to f32 operands, so
the flag can never change numerics through the multiply itself.

Accumulation-order caveat: the products are exact, but the f32 *sums* over
each ``n_r`` block are only bit-identical to ``ref.py`` if XLA reduces both
GEMMs in the same order. On CPU both lower to the same f32 GEMM (bf16
operands are upcast first), so the cross-backend tests hold 0-ulp equality;
on TPU/GPU the mixed-precision GEMM may tile its f32 accumulator
differently at large K, where agreement degrades to last-ulp differences
*before* ADC quantization (``adc_quantize`` snaps most of those away, but
values that land on ADC decision boundaries can flip a code). The
bit-exactness contract is therefore asserted on CPU only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import FPFormat, decompose, pow2i, quantize
from repro.core.mac import adc_quantize

__all__ = ["grmac_matmul_xla", "bf16_products_exact"]


def bf16_products_exact(fmt_x, fmt_w) -> bool:
    """True when every x·w product fits bfloat16's 8 significand bits, so
    the bf16 values-einsum variant is lossless (see module docstring)."""
    nx = getattr(fmt_x, "n_man", None)
    nw = getattr(fmt_w, "n_man", None)
    if nx is None or nw is None:      # IntFormat operands: no such bound
        return False
    return (nx + 1) + (nw + 1) <= 8


@functools.partial(
    jax.jit,
    static_argnames=("fmt_x", "fmt_w", "n_r", "enob", "granularity",
                     "bf16_values", "sanitize", "tag"),
)
def grmac_matmul_xla(
    x: jax.Array,
    wq: jax.Array,
    *,
    fmt_x: FPFormat,
    fmt_w: FPFormat,
    n_r: int = 32,
    enob: float = 8.0,
    granularity: str = "row",
    bf16_values: bool = False,
    sanitize: bool = False,
    tag: str = "",
) -> jax.Array:
    """(M, K) @ (K, N) GR-MAC matmul, fully vectorized; float32 out.

    Inputs pre-scaled to [-1, 1]; ``wq`` already on the weight format grid;
    ``K`` must be a multiple of ``n_r`` (dispatch.py pads).
    ``bf16_values`` runs the block einsums with bf16 operands and an f32
    accumulator when the formats make the products exact (no-op otherwise).
    ``sanitize`` stages the ``repro.analysis.sanitize`` checks on the
    pre-ADC voltage / exponent spans, reported under ``tag``; when False
    (the default) the staged graph is exactly the uninstrumented one.
    """
    x = x.astype(jnp.float32)
    wq = wq.astype(jnp.float32)
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2 and k % n_r == 0
    b = k // n_r
    if sanitize:
        from repro.analysis import sanitize as _san

    op_dtype = (jnp.bfloat16 if bf16_values and bf16_products_exact(
        fmt_x, fmt_w) else jnp.float32)

    def block_einsum(a, bb):
        return jnp.einsum("mbk,bkn->mbn", a.astype(op_dtype),
                          bb.astype(op_dtype),
                          preferred_element_type=jnp.float32)

    xq = quantize(x, fmt_x)
    xb = xq.reshape(m, b, n_r)
    wb = wq.reshape(b, n_r, n)

    if granularity == "conv":
        with jax.named_scope("cim_values"):
            num = block_einsum(xb, wb)
        v = num * (1.0 / n_r)
        if sanitize:
            _san.check_values(tag, v)
        z = adc_quantize(v, enob) * float(n_r)
        return jnp.sum(z, axis=1)

    # input gains 2^{E(xq)} — exponent of the *quantized* value (rounding
    # can promote into the next binade), identical to ref.py's decompose
    _, _, ex = decompose(xq, fmt_x)
    gxb = pow2i(ex).reshape(m, b, n_r)

    if granularity == "row":
        with jax.named_scope("cim_values"):
            num = block_einsum(xb, wb)
        den = jnp.sum(gxb, axis=-1)[:, :, None]          # (M, B, 1)
        scale = 2.0**fmt_x.e_max
        v = num * scale / den
        if sanitize:
            _san.check_values(tag, v)
            exb = ex.reshape(m, b, n_r)
            _san.check_gain_span(
                tag, jnp.max(exb, axis=-1) - jnp.min(exb, axis=-1))
        z = adc_quantize(v, enob) * (den * (1.0 / scale))
        return jnp.sum(z, axis=1)

    if granularity == "unit":
        _, _, ew = decompose(wq, fmt_w)
        gwb = pow2i(ew).reshape(b, n_r, n)
        with jax.named_scope("cim_values"):
            num = block_einsum(xb, wb)
        # gains are powers of two: their bf16 products are exact too
        with jax.named_scope("cim_gains"):
            den = block_einsum(gxb, gwb)
        scale = 2.0 ** (fmt_x.e_max + fmt_w.e_max)
        v = num * scale / den
        if sanitize:
            _san.check_values(tag, v)
            # combined exponent per unit instance: E(x_i) + E(w_i,n)
            comb = (ex.reshape(m, b, n_r)[:, :, :, None]
                    + ew.reshape(b, n_r, n)[None])
            _san.check_gain_span(
                tag, jnp.max(comb, axis=2) - jnp.min(comb, axis=2))
        z = adc_quantize(v, enob) * (den * (1.0 / scale))
        return jnp.sum(z, axis=1)

    raise ValueError(f"unknown granularity {granularity!r}")
