"""Fast pure-XLA GR-MAC backend — the default off-TPU.

Implements the same semantics contract as ``ref.py`` / the Pallas kernel
(see ``ref.py`` for the math), but is written for throughput on CPU/GPU
rather than as a readable oracle or a TPU lowering:

* the K dimension is reshaped into ``(K / n_r, n_r)`` sub-blocks and every
  gain-ranged partial dot product runs as **one batched einsum per
  operand pair** — there is no Python loop over blocks and no 128-padding
  requirement (only ``K % n_r == 0``, handled by ``dispatch.py``);
  stacking the ``unit`` values/gain matmuls into a single 6-D contraction
  was measured *slower* than two plain batched GEMMs (XLA CPU lowers the
  extra stacking dim poorly), so unit runs two einsums;
* quantization / exponent extraction reuse the exact grid primitives from
  ``core.formats`` (frexp + ldexp), so the output is bit-identical to
  ``grmac_matmul_ref`` — the cross-backend tests assert equality at 0 ulp
  tolerance on every granularity.

The whole function is jit-compiled with static format/shape knobs and is
vmap- and grad-safe (pure ``jnp``; gradients follow the usual
straight-through convention of ``jnp.round``). Interpret-mode Pallas runs
the same shapes ~3 orders of magnitude slower; ``benchmarks/kernel_bench.py
--backend all`` measures the gap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import FPFormat, decompose, pow2i, quantize
from repro.core.mac import adc_quantize

__all__ = ["grmac_matmul_xla"]


@functools.partial(
    jax.jit,
    static_argnames=("fmt_x", "fmt_w", "n_r", "enob", "granularity"),
)
def grmac_matmul_xla(
    x: jax.Array,
    wq: jax.Array,
    *,
    fmt_x: FPFormat,
    fmt_w: FPFormat,
    n_r: int = 32,
    enob: float = 8.0,
    granularity: str = "row",
) -> jax.Array:
    """(M, K) @ (K, N) GR-MAC matmul, fully vectorized; float32 out.

    Inputs pre-scaled to [-1, 1]; ``wq`` already on the weight format grid;
    ``K`` must be a multiple of ``n_r`` (dispatch.py pads).
    """
    x = x.astype(jnp.float32)
    wq = wq.astype(jnp.float32)
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2 and k % n_r == 0
    b = k // n_r

    xq = quantize(x, fmt_x)
    xb = xq.reshape(m, b, n_r)
    wb = wq.reshape(b, n_r, n)

    if granularity == "conv":
        num = jnp.einsum(
            "mbk,bkn->mbn", xb, wb, preferred_element_type=jnp.float32)
        z = adc_quantize(num * (1.0 / n_r), enob) * float(n_r)
        return jnp.sum(z, axis=1)

    # input gains 2^{E(xq)} — exponent of the *quantized* value (rounding
    # can promote into the next binade), identical to ref.py's decompose
    _, _, ex = decompose(xq, fmt_x)
    gxb = pow2i(ex).reshape(m, b, n_r)

    if granularity == "row":
        num = jnp.einsum(
            "mbk,bkn->mbn", xb, wb, preferred_element_type=jnp.float32)
        den = jnp.sum(gxb, axis=-1)[:, :, None]          # (M, B, 1)
        scale = 2.0**fmt_x.e_max
        z = adc_quantize(num * scale / den, enob) * (den * (1.0 / scale))
        return jnp.sum(z, axis=1)

    if granularity == "unit":
        _, _, ew = decompose(wq, fmt_w)
        gwb = pow2i(ew).reshape(b, n_r, n)
        num = jnp.einsum(
            "mbk,bkn->mbn", xb, wb, preferred_element_type=jnp.float32)
        den = jnp.einsum(
            "mbk,bkn->mbn", gxb, gwb, preferred_element_type=jnp.float32)
        scale = 2.0 ** (fmt_x.e_max + fmt_w.e_max)
        z = adc_quantize(num * scale / den, enob) * (den * (1.0 / scale))
        return jnp.sum(z, axis=1)

    raise ValueError(f"unknown granularity {granularity!r}")
