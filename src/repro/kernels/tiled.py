"""Tiled, fused GR-MAC backend — wins the large-M (training-shape) regime.

``ref.py`` and ``xla.py`` materialize the full ``(M, B, N)`` numerator (and,
for ``unit``, denominator) before the elementwise ADC epilogue runs.  At
training shapes (``train_large_m`` 2048x768x3072: M*B*N = 150M elements,
~600 MB per f32 intermediate) that turns the op bandwidth-bound: the
den / ``adc_quantize`` / renorm traffic streams each intermediate through
DRAM several times and dominates the GEMM FLOPs — the measured result is
the vectorized ``xla`` backend *losing* to the readable oracle on ``row``
granularity (experiments/bench/kernel_bench.json).

This backend restructures the computation as a ``lax.scan`` over M-tiles
(and optionally N-tiles): each tile body runs

    block-GEMM -> den -> adc_quantize -> renorm -> block-sum

on a ``(tile_m, B, tile_n)`` slab sized to stay resident in cache, so the
``(M, B, N)`` intermediates never exist and the elementwise epilogue reads
and writes cache lines the GEMM just touched.  This is the software
realization of the throughput-per-byte discipline the paper argues for in
hardware — normalization/ADC overhead stays off the critical (bandwidth)
path — and the same loop-reshaping lever IMAGINE applies to the analog
accumulation itself.

Numerics: each tile computes *exactly* the per-element expressions of
``ref.py`` (same ``quantize``/``decompose``/``pow2i`` grid primitives, same
einsum contraction over the ``n_r`` block, same block-sum reduction order),
so the output is bit-identical to the oracle at 0 ulp on every granularity
— asserted across tile shapes in tests/test_kernels.py and
tests/test_properties.py.  The ``bf16_values`` variant mirrors
``xla.py`` (exact products when the operand formats carry <= 8 significand
bits combined; silent f32 fallback otherwise).

Tile-size defaults target a ~12 MiB slab (``default_tile_m`` /
``_SLAB_BUDGET_BYTES``, the measured CPU sweet spot); the dispatch layer
(``kernels.dispatch``) can override per shape, either from its static
heuristic or from a measured autotune plan (``REPRO_GRMAC_AUTOTUNE=1``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.formats import FPFormat, decompose, pow2i, quantize
from repro.core.mac import adc_quantize

from .xla import bf16_products_exact

__all__ = ["grmac_matmul_tiled", "default_tile_m", "pad_to_multiple"]

# Target footprint of the (tile_m, B, tile_n) f32 slab each tile body
# materializes. ~12 MiB (slab + epilogue temporaries stay inside a shared
# L3 partition) measured fastest at train_large_m: tile_m=32 beat 8/16/64/128
# and every N-tiled variant on CPU (see experiments/bench/kernel_bench.json).
_SLAB_BUDGET_BYTES = 12 << 20


def default_tile_m(k: int, n: int, n_r: int, tile_n: int = 0) -> int:
    """Largest power-of-two M-tile whose (tile_m, K/n_r, tile_n or N) f32
    slab fits the cache budget, clamped to [8, 256]."""
    blocks = max(1, k // max(1, n_r))
    ncol = tile_n if tile_n else n
    rows = _SLAB_BUDGET_BYTES // max(1, blocks * ncol * 4)
    tm = 8
    while tm * 2 <= rows and tm < 256:
        tm *= 2
    return tm


def pad_to_multiple(x: jax.Array, axis: int, mult: int) -> jax.Array:
    """Zero-pad ``axis`` up to a multiple of ``mult`` (shared padding
    contract — see kernels/dispatch.py's module docstring)."""
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("fmt_x", "fmt_w", "n_r", "enob", "granularity",
                     "tile_m", "tile_n", "bf16_values", "sanitize", "tag"),
)
def grmac_matmul_tiled(
    x: jax.Array,
    wq: jax.Array,
    *,
    fmt_x: FPFormat,
    fmt_w: FPFormat,
    n_r: int = 32,
    enob: float = 8.0,
    granularity: str = "row",
    tile_m: int = 0,
    tile_n: int = 0,
    bf16_values: bool = False,
    sanitize: bool = False,
    tag: str = "",
) -> jax.Array:
    """(M, K) @ (K, N) GR-MAC matmul, fused per M(xN)-tile; float32 out.

    Inputs pre-scaled to [-1, 1]; ``wq`` already on the weight format grid;
    ``K`` must be a multiple of ``n_r`` (dispatch.py pads).  ``tile_m`` /
    ``tile_n`` need not divide M / N (zero-padded rows/cols are computed and
    sliced away; padding is exact — see dispatch.py's padding contract).
    ``tile_m=0`` picks ``default_tile_m``; ``tile_n=0`` disables N-tiling.
    ``sanitize``/``tag`` stage the ``repro.analysis.sanitize`` checks per
    tile (structurally absent when ``sanitize=False``, the default).
    """
    if granularity not in ("conv", "row", "unit"):
        raise ValueError(f"unknown granularity {granularity!r}")
    x = x.astype(jnp.float32)
    wq = wq.astype(jnp.float32)
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2 and k % n_r == 0
    blocks = k // n_r
    if tile_m <= 0:
        tile_m = default_tile_m(k, n, n_r, tile_n)
    tn = tile_n if 0 < tile_n < n else 0
    if sanitize:
        from repro.analysis import sanitize as _san

    op_dtype = (jnp.bfloat16 if bf16_values and bf16_products_exact(
        fmt_x, fmt_w) else jnp.float32)

    def block_einsum(a, bb):
        return jnp.einsum("mbk,bkn->mbn", a.astype(op_dtype),
                          bb.astype(op_dtype),
                          preferred_element_type=jnp.float32)

    def _exponents(g):
        # gains are exact powers of two: frexp(2^e) = (0.5, e + 1)
        return jnp.frexp(g)[1] - 1

    def fused_tile(xb_t, gxb_t, wb_t, gwb_t):
        """One resident slab: GEMM -> den -> ADC -> renorm -> block-sum.

        Shapes: xb_t/gxb_t (tile_m, B, n_r); wb_t/gwb_t (B, n_r, cols).
        Per-element math is ref.py's, verbatim — the 0-ulp contract.
        """
        with jax.named_scope("cim_values"):
            num = block_einsum(xb_t, wb_t)
        if granularity == "conv":
            v = num * (1.0 / n_r)
            if sanitize:
                _san.check_values(tag, v)
            z = adc_quantize(v, enob) * float(n_r)
        elif granularity == "row":
            den = jnp.sum(gxb_t, axis=-1)[:, :, None]        # (tile_m, B, 1)
            scale = 2.0**fmt_x.e_max
            v = num * scale / den
            if sanitize:
                _san.check_values(tag, v)
                ex_t = _exponents(gxb_t)
                _san.check_gain_span(
                    tag, jnp.max(ex_t, axis=-1) - jnp.min(ex_t, axis=-1))
            z = adc_quantize(v, enob) * (den * (1.0 / scale))
        else:  # unit
            with jax.named_scope("cim_gains"):
                den = block_einsum(gxb_t, gwb_t)
            scale = 2.0 ** (fmt_x.e_max + fmt_w.e_max)
            v = num * scale / den
            if sanitize:
                _san.check_values(tag, v)
                comb = (_exponents(gxb_t)[:, :, :, None]
                        + _exponents(gwb_t)[None])
                _san.check_gain_span(
                    tag, jnp.max(comb, axis=2) - jnp.min(comb, axis=2))
            z = adc_quantize(v, enob) * (den * (1.0 / scale))
        return jnp.sum(z, axis=1)                            # (tile_m, cols)

    # Weight-side operands are laid out once, outside the scan.
    npad = n if not tn else n + ((-n) % tn)
    wp = pad_to_multiple(wq, 1, tn) if tn else wq
    wb = wp.reshape(blocks, n_r, npad)
    gwb = None
    if granularity == "unit":
        _, _, ew = decompose(wp, fmt_w)
        gwb = pow2i(ew).reshape(blocks, n_r, npad)
    if tn:
        # (Tn, B, n_r, tn): leading axis scanned per N-tile
        wt = wb.reshape(blocks, n_r, npad // tn, tn).transpose(2, 0, 1, 3)
        gwt = (gwb.reshape(blocks, n_r, npad // tn, tn).transpose(2, 0, 1, 3)
               if gwb is not None else None)

    xp = pad_to_multiple(x, 0, tile_m)
    xs = xp.reshape(xp.shape[0] // tile_m, tile_m, k)

    def m_body(_, xt):
        xq = quantize(xt, fmt_x)
        xb_t = xq.reshape(tile_m, blocks, n_r)
        gxb_t = None
        if granularity != "conv":
            _, _, ex = decompose(xq, fmt_x)
            gxb_t = pow2i(ex).reshape(tile_m, blocks, n_r)
        if not tn:
            return None, fused_tile(xb_t, gxb_t, wb, gwb)
        if gwt is None:
            _, outs = lax.scan(
                lambda c, w_t: (None, fused_tile(xb_t, gxb_t, w_t, None)),
                None, wt)
        else:
            _, outs = lax.scan(
                lambda c, wg: (None, fused_tile(xb_t, gxb_t, wg[0], wg[1])),
                None, (wt, gwt))
        # (Tn, tile_m, tn) -> (tile_m, N)
        return None, outs.transpose(1, 0, 2).reshape(tile_m, npad)[:, :n]

    _, out = lax.scan(m_body, None, xs)
    return out.reshape(-1, n)[:m]
