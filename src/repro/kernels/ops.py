"""Public CIM matmul ops used by the model layers.

``cim_matmul(x, w, cfg, site=...)`` is a drop-in einsum-style matmul over
the last dim of ``x``:   (..., K) @ (K, N) -> (..., N).

Pipeline
--------
0. per-site policy: ``cfg.for_site(site)`` resolves which design (or
   "off") runs at this call site (``CIMConfig.site_overrides`` first,
   the legacy ``apply_to`` families otherwise), and the contract
   ``(site, M, K, N, design)`` is recorded into the active
   ``core.costs.CostLedger`` when a trace is running — this is the single
   choke point that keeps energy accounting structurally tied to the
   models (see core/costs.py).
1. dynamic pre-scale: activations are normalized into [-1, 1] by their
   per-tensor absmax (the CIM full-scale reference); weights likewise.
2. mode dispatch:
     off        exact matmul (digital baseline)
     fakequant  format-grid quantization of x and w, exact accumulation
     grmac      full GR-MAC block simulation, executed by the backend
                planned through ``kernels.dispatch`` (``cfg.backend`` or
                the ``backend=`` override; "auto" plans per shape — small-M
                decode hits the batched-einsum XLA path, large-M training
                shapes the fused tiled path, TPU the Pallas kernel;
                ``cfg.tile_m``/``cfg.tile_n`` pin the tile sizes)
3. straight-through gradients: the backward pass applies the exact-matmul
   VJP to the *raw* (unquantized, unscaled) saved operands — the standard
   STE estimator — so the op is trainable. (The backward is therefore
   digital by construction; only forward contracts hit the analog array
   and the ledger.)

``logical_n`` overrides the N recorded into the ledger (the LM head
records the true ``vocab_size``, not the 256-aligned ``padded_vocab`` —
pad columns are masked and would never be mapped onto an array); the
matmul itself always runs at the physical shapes.

All GR-MAC backends implement the same contract and are cross-validated in
tests/test_kernels.py.

Audit markers
-------------
Every call is wrapped in a ``jax.named_scope`` marker
``cim_<site>_m<M>_k<K>_n<N>`` carrying the *ledger* contract (logical N for
the LM head), and the contraction that realizes it carries a nested
``cim_values`` scope (``cim_gains`` for the unit-normalization denominator,
``dig_ste_bwd`` for the digital STE backward). The scopes are metadata-only
(they change no jaxpr primitive and no numerics); the jaxpr ledger audit
(``repro.analysis.jaxpr_audit``) walks traced model functions and proves
every ``dot_general`` is attributable to one of these markers — or to an
explicitly declared digital ``dig_*`` scope — with call counts matching the
``CostLedger`` exactly.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.cim_config import CIMConfig
from repro.core.formats import IntFormat, quantize, quantize_any

from .dispatch import grmac_matmul, resolve_backend

__all__ = ["cim_matmul", "site_marker"]

_EPS = 1e-12


def site_marker(site: Optional[str], m: int, k: int, n: int) -> str:
    """The audit marker naming one ledger contract: parsed back by
    ``repro.analysis.jaxpr_audit`` (site names contain underscores, so the
    ``_m<digits>`` suffix anchors the parse)."""
    return f"cim_{site or 'unsited'}_m{m}_k{k}_n{n}"


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _cim_matmul_2d(x, w, cfg: CIMConfig, backend: str, site: str):
    """(M, K) @ (K, N) with CIM numerics and STE gradients. ``site`` is
    metadata only (sanitizer context tag); it never changes numerics."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    sx = jnp.maximum(jnp.max(jnp.abs(x32)), _EPS)
    sw = jnp.maximum(jnp.max(jnp.abs(w32)), _EPS)
    xn = x32 / sx
    wn = w32 / sw
    if cfg.mode == "fakequant":
        # fmt_x may be an IntFormat (the DSE sweeps the INT ladder and
        # per-site overrides can carry its choices); fmt_w is always FP
        with jax.named_scope("cim_values"):
            out = quantize_any(xn, cfg.fmt_x) @ quantize(wn, cfg.fmt_w)
    elif cfg.mode == "grmac":
        if isinstance(cfg.fmt_x, IntFormat):
            raise NotImplementedError(
                "grmac execution with an IntFormat input is not "
                "implemented (the gr_int signal chain is priced "
                "analytically by core.costs/core.dse but has no kernel "
                "backend): deploy INT per-site designs with "
                "mode='fakequant', or pick an FP format")
        out = grmac_matmul(
            xn,
            quantize(wn, cfg.fmt_w),
            fmt_x=cfg.fmt_x,
            fmt_w=cfg.fmt_w,
            n_r=cfg.n_r,
            enob=cfg.resolved_enob(),
            granularity=cfg.granularity,
            backend=backend,
            tile_m=cfg.tile_m,
            tile_n=cfg.tile_n,
            tag=site,
        )
    else:  # off
        with jax.named_scope("cim_values"):
            out = xn @ wn
    return (out * (sx * sw)).astype(dtype)


def _fwd(x, w, cfg, backend, site):
    out = _cim_matmul_2d(x, w, cfg, backend, site)
    return out, (x, w)


def _bwd(cfg, backend, site, res, g):
    x, w = res
    # Straight-through: gradients flow as if the matmul were exact. The
    # dig_ste_bwd scope declares these contractions digital-by-design to
    # the jaxpr ledger audit (the backward never hits the analog array).
    with jax.named_scope("dig_ste_bwd"):
        gx = (g @ w.T.astype(g.dtype)).astype(x.dtype)
        gw = (x.T.astype(g.dtype) @ g).astype(w.dtype)
    return gx, gw


_cim_matmul_2d.defvjp(_fwd, _bwd)


def cim_matmul(
    x: jax.Array,
    w: jax.Array,
    cfg: Optional[CIMConfig] = None,
    *,
    site: Optional[str] = None,
    backend: Optional[str] = None,
    use_kernel: Optional[bool] = None,
    logical_n: Optional[int] = None,
) -> jax.Array:
    """(..., K) @ (K, N) with CIM numerics per ``cfg.for_site(site)``
    (None/off = exact digital matmul).

    ``site`` names the model call site (see ``core.cim_config.SITES``);
    ``site=None`` treats ``cfg`` as already resolved (external callers).
    Backend precedence: ``backend=`` argument > ``cfg.backend`` > platform
    auto-selection (see ``kernels.dispatch``). ``use_kernel`` is the legacy
    boolean knob: True forces the Pallas kernel, False the fast XLA path.
    """
    eff = cfg.for_site(site) if cfg is not None else None
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    m = math.prod(lead)
    ledger_n = n if logical_n is None else logical_n
    costs.record_matmul(site, m, k, ledger_n, eff)
    marker = site_marker(site, m, k, ledger_n)
    if eff is None or not eff.enabled:
        with jax.named_scope(marker), jax.named_scope("cim_values"):
            return x @ w
    if backend is None:
        if use_kernel is not None:
            backend = "pallas" if use_kernel else "xla"
        else:
            backend = eff.backend
    # resolve outside the custom_vjp so the nondiff arg is a concrete,
    # hashable backend name (stable jit cache key)
    backend = resolve_backend(backend)
    with jax.named_scope(marker):
        out = _cim_matmul_2d(x.reshape(-1, k), w, eff, backend, site or "")
    return out.reshape(*lead, n)
