"""Public CIM matmul ops used by the model layers.

``cim_matmul(x, w, cfg)`` is a drop-in einsum-style matmul over the last dim
of ``x``:   (..., K) @ (K, N) -> (..., N).

Pipeline
--------
1. dynamic pre-scale: activations are normalized into [-1, 1] by their
   per-tensor absmax (the CIM full-scale reference); weights likewise.
2. mode dispatch:
     off        exact matmul (digital baseline)
     fakequant  format-grid quantization of x and w, exact accumulation
     grmac      full GR-MAC block simulation (ref path by default; the
                Pallas kernel on TPU or when use_kernel=True)
3. straight-through gradients: the backward pass uses the dequantized
   operands (standard QAT estimator), so the op is trainable.

The ref path and the Pallas kernel implement the same contract and are
cross-validated in tests/test_kernels.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.cim_config import CIMConfig
from repro.core.formats import quantize

from .grmac_matmul import grmac_matmul_pallas
from .ref import grmac_matmul_ref

__all__ = ["cim_matmul"]

_EPS = 1e-12


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _grmac_2d(xn, wn, cfg: CIMConfig, use_kernel: bool):
    """Normalized (M,K) @ (K,N) through the GR-MAC simulation."""
    m, k = xn.shape
    n = wn.shape[1]
    wq = quantize(wn, cfg.fmt_w)
    if use_kernel:
        bm, bn, bk = 128, 128, max(128, cfg.n_r)
        xp = _pad_to(_pad_to(xn, 0, bm), 1, bk)
        wp = _pad_to(_pad_to(wq, 0, bk), 1, bn)
        out = grmac_matmul_pallas(
            xp,
            wp,
            fmt_x=cfg.fmt_x,
            fmt_w=cfg.fmt_w,
            n_r=cfg.n_r,
            enob=cfg.resolved_enob(),
            granularity=cfg.granularity,
            block_m=bm,
            block_n=bn,
            block_k=bk,
        )
        return out[:m, :n]
    xp = _pad_to(xn, 1, cfg.n_r)
    wp = _pad_to(wq, 0, cfg.n_r)
    return grmac_matmul_ref(
        xp,
        wp,
        fmt_x=cfg.fmt_x,
        fmt_w=cfg.fmt_w,
        n_r=cfg.n_r,
        enob=cfg.resolved_enob(),
        granularity=cfg.granularity,
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _cim_matmul_2d(x, w, cfg: CIMConfig, use_kernel: bool):
    """(M, K) @ (K, N) with CIM numerics and STE gradients."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    sx = jnp.maximum(jnp.max(jnp.abs(x32)), _EPS)
    sw = jnp.maximum(jnp.max(jnp.abs(w32)), _EPS)
    xn = x32 / sx
    wn = w32 / sw
    if cfg.mode == "fakequant":
        out = quantize(xn, cfg.fmt_x) @ quantize(wn, cfg.fmt_w)
    elif cfg.mode == "grmac":
        out = _grmac_2d(xn, wn, cfg, use_kernel)
    else:  # off
        out = xn @ wn
    return (out * (sx * sw)).astype(dtype)


def _fwd(x, w, cfg, use_kernel):
    out = _cim_matmul_2d(x, w, cfg, use_kernel)
    return out, (x, w)


def _bwd(cfg, use_kernel, res, g):
    x, w = res
    # Straight-through: gradients flow as if the matmul were exact.
    gx = (g @ w.T.astype(g.dtype)).astype(x.dtype)
    gw = (x.T.astype(g.dtype) @ g).astype(w.dtype)
    return gx, gw


_cim_matmul_2d.defvjp(_fwd, _bwd)


def cim_matmul(
    x: jax.Array,
    w: jax.Array,
    cfg: Optional[CIMConfig] = None,
    *,
    use_kernel: Optional[bool] = None,
) -> jax.Array:
    """(..., K) @ (K, N) with CIM numerics per ``cfg`` (None/off = exact)."""
    if cfg is None or not cfg.enabled:
        return x @ w
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    lead = x.shape[:-1]
    k = x.shape[-1]
    out = _cim_matmul_2d(x.reshape(-1, k), w, cfg, use_kernel)
    return out.reshape(*lead, w.shape[-1])
