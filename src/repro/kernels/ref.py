"""Pure-jnp oracle for the GR-MAC matmul kernels.

Semantics contract (shared with the Pallas kernel, validated in tests):

The K dimension is processed in blocks of ``n_r`` (one analog CIM column
accumulation + one ADC conversion per block).  Inputs are assumed already
*pre-scaled* into [-1, 1]; weights arrive already quantized onto their format
grid.  All math in float32.

  row normalization:
      xq   = Q_fmt_x(x)                            (per element)
      g    = 2^{E(xq)}                             (input gains)
      num  = xq_blk @ wq_blk                       (values matmul)
      den  = Σ_k g_blk                             (per row)
      v    = num * 2^{e_max_x} / den               (compute-line voltage)
      out += Q_ADC(v) * den * 2^{-e_max_x}

  unit normalization:
      additionally gw = 2^{E(wq)} and den = g_blk @ gw_blk (per row×col),
      v = num * 2^{e_max_x + e_max_w} / den, renormalized accordingly.

  conv (conventional FP->INT direct accumulation, the paper's baseline):
      v = (xq_blk @ wq_blk) / n_r;  out += Q_ADC(v) * n_r
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import FPFormat, decompose, pow2i, quantize
from repro.core.mac import adc_quantize

__all__ = ["grmac_matmul_ref"]


def _block(x: jnp.ndarray, n_r: int) -> jnp.ndarray:
    m, k = x.shape
    assert k % n_r == 0, f"K={k} not a multiple of n_r={n_r}"
    return x.reshape(m, k // n_r, n_r)


def grmac_matmul_ref(
    x: jnp.ndarray,
    wq: jnp.ndarray,
    *,
    fmt_x: FPFormat,
    fmt_w: FPFormat,
    n_r: int = 32,
    enob: float = 8.0,
    granularity: str = "row",
    sanitize: bool = False,
    tag: str = "",
) -> jnp.ndarray:
    """Reference GR-MAC matmul: (M, K) @ (K, N) -> (M, N), float32.

    ``sanitize``/``tag`` stage the ``repro.analysis.sanitize`` checks on the
    pre-ADC voltage and exponent spans (absent when ``sanitize=False``).
    """
    x = x.astype(jnp.float32)
    wq = wq.astype(jnp.float32)
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2
    if sanitize:
        from repro.analysis import sanitize as _san

    xq = quantize(x, fmt_x)
    xb = _block(xq, n_r)                     # (M, B, n_r)
    wb = wq.reshape(k // n_r, n_r, n)        # (B, n_r, N)

    # values matmul per block: (M, B, N)
    with jax.named_scope("cim_values"):
        num = jnp.einsum("mbk,bkn->mbn", xb, wb,
                         preferred_element_type=jnp.float32)

    if granularity == "conv":
        v = num / n_r
        if sanitize:
            _san.check_values(tag, v)
        z = adc_quantize(v, enob) * n_r
        return jnp.sum(z, axis=1)

    _, _, ex = decompose(xq, fmt_x)
    gx = pow2i(ex)
    gxb = _block(gx, n_r)                    # (M, B, n_r)

    if granularity == "row":
        den = jnp.sum(gxb, axis=-1)          # (M, B)
        v = num * 2.0**fmt_x.e_max / den[:, :, None]
        if sanitize:
            _san.check_values(tag, v)
            exb = _block(ex, n_r)
            _san.check_gain_span(
                tag, jnp.max(exb, axis=-1) - jnp.min(exb, axis=-1))
        z = adc_quantize(v, enob) * (den[:, :, None] * 2.0**-fmt_x.e_max)
        return jnp.sum(z, axis=1)

    if granularity == "unit":
        _, _, ew = decompose(wq, fmt_w)
        gw = pow2i(ew).reshape(k // n_r, n_r, n)
        with jax.named_scope("cim_gains"):
            den = jnp.einsum("mbk,bkn->mbn", gxb, gw,
                             preferred_element_type=jnp.float32)
        scale = 2.0 ** (fmt_x.e_max + fmt_w.e_max)
        v = num * scale / den
        if sanitize:
            _san.check_values(tag, v)
            comb = (_block(ex, n_r)[:, :, :, None]
                    + ew.reshape(k // n_r, n_r, n)[None])
            _san.check_gain_span(
                tag, jnp.max(comb, axis=2) - jnp.min(comb, axis=2))
        z = adc_quantize(v, enob) * (den / scale)
        return jnp.sum(z, axis=1)

    raise ValueError(f"unknown granularity {granularity!r}")
