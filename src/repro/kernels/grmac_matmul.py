"""Pallas TPU kernel for the GR-MAC matmul (deployment-faithful CIM numerics).

TPU mapping of the paper's architecture (DESIGN.md §2):

* one CIM array column accumulation  <->  one ``n_r``-deep K sub-block
* mantissa multiply + charge redistribution  <->  MXU dot over the sub-block
* exponent adder tree (digital)  <->  VPU row-sum of gains (row norm) or a
  second MXU dot ``gx @ gw`` (unit norm), fused in the same VMEM pass
* ADC conversion  <->  mid-tread requantization of the block partial sum

The kernel streams (block_m × block_k) activation tiles and
(block_k × block_n) weight tiles through VMEM, quantizes activations onto the
input format grid in-register (exponent extraction via bitcast — exact, no
transcendentals on the hot path), performs ``block_k / n_r`` gain-ranged
partial dot products, and accumulates the renormalized, ADC-quantized block
outputs into the float32 output tile.

The values matmul runs in bfloat16: both operands live on low-bit format
grids (≤ 5 significant bits), so bf16 products/MXU accumulation are exact.

Shapes must be pre-padded to multiples of the block sizes (see
``dispatch._run_plan``, which also threads the planner's ``tile_m``/
``tile_n`` — rounded up to 128 — into ``block_m``/``block_n``, so the TPU
grid tiles M the same way the host-side tiled backend does); ``block_k``
must be a multiple of ``n_r`` and 128-aligned for the MXU. The per-column
epilogue (den -> ADC -> renorm -> accumulate) is fused in the kernel body,
matching kernels/tiled.py's formulation, and the K sub-block loop rolls
into a ``fori_loop`` past ``_UNROLL_SUBBLOCKS`` columns so large planned
K-tiles don't blow up the lowered kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import pallas_tpu_compiler_params
from repro.core.formats import FPFormat

__all__ = ["grmac_matmul_pallas"]

# Sub-block (n_r-deep column) count up to which the K loop is fully
# unrolled into straight-line MXU dots; beyond it a lax.fori_loop keeps the
# lowered kernel size O(1) in block_k (plans may pick large K-tiles).
_UNROLL_SUBBLOCKS = 8


def _pow2(e: jax.Array) -> jax.Array:
    """Exact 2**e for int32 ``e`` in [-126, 127] via IEEE-754 bit assembly.

    jnp.exp2 is not bit-exact on every backend; grid-exact quantization (and
    exact agreement with ref.py) requires true powers of two.
    """
    return jax.lax.bitcast_convert_type((e + 127) << 23, jnp.float32)


def _quant_decompose(x: jax.Array, fmt: FPFormat):
    """Quantize f32 ``x`` onto the format grid; return (xq, gain=2^E).

    Exponent extraction via IEEE-754 bit manipulation: for positive normal
    f32, floor(log2 a) = ((bits >> 23) & 0xff) - 127. Subnormal-f32 inputs
    (< 2^-126) underflow to the format's lowest bin, which is correct.
    """
    def eff_exp(a):
        bits = jax.lax.bitcast_convert_type(a, jnp.int32)
        floor_log2 = ((bits >> 23) & 0xFF) - 127
        return jnp.clip(floor_log2 + 1 + fmt.e_max, 1, fmt.e_max)

    a = jnp.abs(x)
    e = eff_exp(a)
    lsb = _pow2(e - (fmt.e_max + fmt.n_man + 1))
    q = jnp.round(a / lsb) * lsb
    q = jnp.minimum(q, fmt.max_value)
    xq = jnp.where(x < 0, -q, q)
    # Gain must reflect the exponent of the *quantized* value: rounding can
    # promote a value into the next binade (e.g. 0.499 -> 0.5).
    gain = _pow2(eff_exp(q))
    return xq, gain


def _adc(v: jax.Array, enob: float) -> jax.Array:
    delta = 2.0 / (2.0**enob)
    return jnp.clip(jnp.round(v * (1.0 / delta)) * delta, -1.0, 1.0)


def _kernel(
    x_ref,
    w_ref,
    o_ref,
    *,
    fmt_x: FPFormat,
    fmt_w: FPFormat,
    n_r: int,
    enob: float,
    granularity: str,
    block_k: int,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bk)
    w = w_ref[...].astype(jnp.float32)          # (bk, bn)
    xq, gx = _quant_decompose(x, fmt_x)
    if granularity == "unit":
        # Weights are already on the grid; recover their gains in-register
        # (cheaper than streaming a second K×N operand from HBM).
        _, gw = _quant_decompose(w, fmt_w)

    if granularity not in ("conv", "row", "unit"):
        raise ValueError(granularity)

    xq16 = xq.astype(jnp.bfloat16)
    w16 = w.astype(jnp.bfloat16)

    def sub_block(start, acc):
        """One n_r-deep analog column: MXU dot + fused den/ADC/renorm
        epilogue — the same per-tile formulation kernels/tiled.py scans on
        the host side, so the TPU lowering matches the planned backend."""
        xs = jax.lax.dynamic_slice_in_dim(xq16, start, n_r, axis=1)
        ws = jax.lax.dynamic_slice_in_dim(w16, start, n_r, axis=0)
        num = jnp.dot(xs, ws, preferred_element_type=jnp.float32)
        if granularity == "conv":
            v = num * (1.0 / n_r)
            return acc + _adc(v, enob) * float(n_r)
        if granularity == "row":
            gs = jax.lax.dynamic_slice_in_dim(gx, start, n_r, axis=1)
            den = jnp.sum(gs, axis=1, keepdims=True)             # (bm, 1)
            scale = 2.0**fmt_x.e_max
            v = num * scale / den
            return acc + _adc(v, enob) * (den * (1.0 / scale))
        gs = jax.lax.dynamic_slice_in_dim(gx, start, n_r, axis=1)
        gws = jax.lax.dynamic_slice_in_dim(gw, start, n_r, axis=0)
        den = jnp.dot(gs, gws, preferred_element_type=jnp.float32)
        scale = 2.0 ** (fmt_x.e_max + fmt_w.e_max)
        v = num * scale / den
        return acc + _adc(v, enob) * (den * (1.0 / scale))

    acc = jnp.zeros_like(o_ref)
    n_sub = block_k // n_r
    if n_sub <= _UNROLL_SUBBLOCKS:
        for s in range(n_sub):
            acc = sub_block(s * n_r, acc)
    else:
        # Large planned K-tiles: a rolled loop keeps the lowered kernel
        # O(1) in block_k instead of unrolling hundreds of sub-blocks.
        acc = jax.lax.fori_loop(
            0, n_sub, lambda s, a: sub_block(s * n_r, a), acc)
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=(
        "fmt_x",
        "fmt_w",
        "n_r",
        "enob",
        "granularity",
        "block_m",
        "block_n",
        "block_k",
        "interpret",
    ),
)
def grmac_matmul_pallas(
    x: jax.Array,
    wq: jax.Array,
    *,
    fmt_x: FPFormat,
    fmt_w: FPFormat,
    n_r: int = 32,
    enob: float = 8.0,
    granularity: str = "row",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(M, K) @ (K, N) GR-MAC matmul; inputs pre-scaled to [-1, 1]."""
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{k})x({k2},{n}) must be padded to blocks "
        f"({block_m},{block_k},{block_n}) — see ops.cim_matmul"
    )
    assert block_k % n_r == 0

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _kernel,
        fmt_x=fmt_x,
        fmt_w=fmt_w,
        n_r=n_r,
        enob=enob,
        granularity=granularity,
        block_k=block_k,
    )
    grid = (m // block_m, n // block_n, k // block_k)
    call_kwargs = {}
    if not interpret:
        # interpret mode ignores TPU compiler params (and some JAX versions
        # reject them there); only attach them for real TPU lowering.
        params = pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if params is not None:
            call_kwargs["compiler_params"] = params
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
        **call_kwargs,
    )(x.astype(jnp.float32), wq.astype(jnp.float32))
