"""Backend dispatch for the GR-MAC matmul.

One entry point, ``grmac_matmul(x, wq, ..., backend=...)``, selects among
the implementations and owns the shape-padding contract so every caller
(``ops.cim_matmul``, benchmarks, tests) sees plain ``(M, K) @ (K, N)``:

=================  ==========================================================
backend            implementation
=================  ==========================================================
``auto``           ``pallas`` on TPU, ``xla`` everywhere else (the default;
                   also overridable with ``REPRO_GRMAC_BACKEND``)
``xla``            ``xla.grmac_matmul_xla`` — fully-vectorized batched
                   einsum, jit/vmap/grad-safe, fast on CPU/GPU
``pallas``         ``grmac_matmul.grmac_matmul_pallas`` — the TPU kernel
                   (VMEM-streaming MXU lowering); off-TPU it silently runs
                   in interpret mode, so only pick it explicitly on TPU
``pallas_interpret``  the Pallas kernel forced through the interpreter —
                   a *debug* backend for cross-checking the TPU lowering's
                   semantics off-TPU; orders of magnitude slower than
                   ``xla`` (see ``benchmarks/kernel_bench.py``)
``ref``            ``ref.grmac_matmul_ref`` — the readable pure-jnp oracle
=================  ==========================================================

Padding: every backend requires ``K % n_r == 0`` (an analog column always
has ``n_r`` physical rows; zero-padded entries still contribute their
minimum-capacitance gain to the block denominator, exactly like unused
hardware rows). The Pallas backends additionally need 128-aligned M/N/K
tiles. ``grmac_matmul`` pads with zeros and slices the result, so both
families see the *same* padded K blocks and agree numerically.
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.formats import FPFormat

from .grmac_matmul import grmac_matmul_pallas
from .ref import grmac_matmul_ref
from .xla import grmac_matmul_xla

__all__ = ["BACKENDS", "resolve_backend", "grmac_matmul"]

BACKENDS = ("auto", "xla", "pallas", "pallas_interpret", "ref")

_ENV_VAR = "REPRO_GRMAC_BACKEND"
# Opt-in bf16 values-einsum variant of the XLA backend (products exact when
# the operand formats carry <= 8 significand bits between them; see
# kernels/xla.py for the accumulation-order caveat). Read per call so tests
# can monkeypatch the environment.
_BF16_ENV = "REPRO_GRMAC_BF16_VALUES"


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve ``backend`` (None/"auto" -> env var -> platform default)."""
    b = backend or "auto"
    if b == "auto":
        b = os.environ.get(_ENV_VAR, "auto")
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "xla"
    if b not in BACKENDS:
        raise ValueError(
            f"unknown GR-MAC backend {b!r}; expected one of {BACKENDS}")
    return b


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def grmac_matmul(
    x: jax.Array,
    wq: jax.Array,
    *,
    fmt_x: FPFormat,
    fmt_w: FPFormat,
    n_r: int = 32,
    enob: float = 8.0,
    granularity: str = "row",
    backend: Optional[str] = None,
) -> jax.Array:
    """(M, K) @ (K, N) GR-MAC matmul via the selected backend.

    ``x`` pre-scaled to [-1, 1]; ``wq`` already on the weight format grid.
    Arbitrary M/N/K (padding handled here); float32 output.
    """
    b = resolve_backend(backend)
    m, k = x.shape
    n = wq.shape[1]
    kwargs = dict(fmt_x=fmt_x, fmt_w=fmt_w, n_r=n_r, enob=enob,
                  granularity=granularity)

    if b in ("pallas", "pallas_interpret"):
        bm, bn, bk = 128, 128, math.lcm(128, n_r)
        xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
        wp = _pad_to(_pad_to(wq, 0, bk), 1, bn)
        out = grmac_matmul_pallas(
            xp, wp, block_m=bm, block_n=bn, block_k=bk,
            interpret=(True if b == "pallas_interpret" else None), **kwargs)
        return out[:m, :n]

    xp = _pad_to(x, 1, n_r)
    wp = _pad_to(wq, 0, n_r)
    if b == "xla":
        bf16 = os.environ.get(_BF16_ENV, "0") not in ("", "0")
        return grmac_matmul_xla(xp, wp, bf16_values=bf16, **kwargs)
    return grmac_matmul_ref(xp, wp, **kwargs)
