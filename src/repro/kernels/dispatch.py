"""Backend dispatch + shape-aware autotuning for the GR-MAC matmul.

One entry point, ``grmac_matmul(x, wq, ..., backend=...)``, selects among
the implementations and owns the shape-padding contract so every caller
(``ops.cim_matmul``, benchmarks, tests) sees plain ``(M, K) @ (K, N)``:

=================  ==========================================================
backend            implementation
=================  ==========================================================
``auto``           shape-aware plan (the default): ``pallas`` on TPU;
                   off-TPU the planner picks ``xla`` for small M (decode
                   shapes) and ``tiled`` for large M (training shapes),
                   either from the static heuristic or from a measured,
                   persisted autotune plan (see *Autotuning* below).
                   Overridable with ``REPRO_GRMAC_BACKEND``.
``xla``            ``xla.grmac_matmul_xla`` — fully-vectorized batched
                   einsum; fastest at small M, but materializes the full
                   ``(M, B, N)`` intermediates (bandwidth-bound at large M)
``tiled``          ``tiled.grmac_matmul_tiled`` — ``lax.scan`` over
                   M(xN)-tiles with the den/ADC/renorm epilogue fused in
                   the tile body; the large-M winner (>=2x over both
                   ``xla`` and ``ref`` at train_large_m on CPU)
``pallas``         ``grmac_matmul.grmac_matmul_pallas`` — the TPU kernel
                   (VMEM-streaming MXU lowering); off-TPU it silently runs
                   in interpret mode, so only pick it explicitly on TPU
``pallas_interpret``  the Pallas kernel forced through the interpreter —
                   a *debug* backend for cross-checking the TPU lowering's
                   semantics off-TPU; orders of magnitude slower than
                   ``xla`` (see ``benchmarks/kernel_bench.py``)
``ref``            ``ref.grmac_matmul_ref`` — the readable pure-jnp oracle
=================  ==========================================================

Autotuning
----------
``plan_for`` maps ``(M, K, N, granularity, fmt_x, fmt_w, n_r, platform)``
to a ``Plan(backend, tile_m, tile_n)``:

1. an in-memory plan table (warm path: zero overhead after first use);
2. the persisted JSON plan cache (``REPRO_GRMAC_PLAN_CACHE``, default
   ``~/.cache/repro/grmac_plans.json``) — plans measured once are reused
   across processes, so serving/training never pay the probe twice. The
   file carries a schema ``version`` (``PLAN_CACHE_VERSION``); caches
   written under a different version — or pre-versioned flat files — are
   ignored with a warning and rewritten on the next persisted plan, so
   growing the candidate space (tile_n, bf16-values) can never silently
   serve stale measurements;
3. with ``REPRO_GRMAC_AUTOTUNE=1``: a micro-autotune that times each
   candidate ``(backend, tile_m, tile_n)`` on synthetic operands of the
   requested shape, persists the winner, and returns it (skipped inside
   jax traces — the heuristic answers there and the next eager call
   probes);
4. otherwise: the static heuristic — ``pallas`` on TPU, ``tiled`` when
   ``M >= 64`` (the measured CPU crossover), else ``xla``.

Padding: every backend requires ``K % n_r == 0`` (an analog column always
has ``n_r`` physical rows; zero-padded entries still contribute their
minimum-capacitance gain to the block denominator, exactly like unused
hardware rows). The Pallas backends additionally need 128-aligned M/N/K
tiles. ``grmac_matmul`` pads with zeros and slices the result, so all
families see the *same* padded K blocks and agree numerically.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import warnings
from typing import Dict, Optional

import jax

from repro.core.formats import FPFormat

from .grmac_matmul import grmac_matmul_pallas
from .ref import grmac_matmul_ref
from .tiled import default_tile_m, grmac_matmul_tiled, pad_to_multiple
from .xla import grmac_matmul_xla

__all__ = [
    "BACKENDS",
    "PLAN_CACHE_VERSION",
    "Plan",
    "resolve_backend",
    "plan_for",
    "plan_cache_path",
    "clear_plan_cache",
    "grmac_matmul",
]

BACKENDS = ("auto", "xla", "tiled", "pallas", "pallas_interpret", "ref")

_ENV_VAR = "REPRO_GRMAC_BACKEND"
# Opt-in bf16 values-einsum variant of the XLA/tiled backends (products exact
# when the operand formats carry <= 8 significand bits between them; see
# kernels/xla.py for the accumulation-order caveat). Read per call so tests
# can monkeypatch the environment.
_BF16_ENV = "REPRO_GRMAC_BF16_VALUES"
# Opt-in numerics sanitizer (repro.analysis.sanitize): instruments the
# xla/tiled/ref backends with in-graph NaN/Inf, pre-ADC overflow and
# gain-range-limit checks via jax.debug.callback. Read per call (like the
# bf16 flag) so tests can monkeypatch the environment; when unset the
# backends receive sanitize=False / tag="" and stage *zero* extra
# primitives (bit-identical outputs, same jit cache keys as before).
# The Pallas backends are not instrumented (checks cannot run inside a
# pallas_call); sanitize runs are expected on xla/tiled/ref.
_SAN_ENV = "REPRO_SANITIZE"
# Opt-in micro-autotune (measured-once-then-cached planning).
_AUTOTUNE_ENV = "REPRO_GRMAC_AUTOTUNE"
# Override for the persisted plan-cache location.
_PLAN_CACHE_ENV = "REPRO_GRMAC_PLAN_CACHE"
# Plan-cache schema version. Bump when the plan record or the candidate
# space changes meaning (e.g. tile_n semantics, bf16-values candidates):
# a cache written by a different schema is ignored with a warning rather
# than silently serving plans measured under different rules, and the
# next persisted plan rewrites the file under the current version.
PLAN_CACHE_VERSION = 1

# Measured CPU crossover (benchmarks/kernel_bench.py): at M=16 the batched
# einsum wins; from M=64 the fused tiles win at every granularity.
_TILED_MIN_M = 64


@dataclasses.dataclass(frozen=True)
class Plan:
    """A dispatch decision: which backend runs a shape, with which tiles.

    ``tile_m``/``tile_n`` are only meaningful for ``tiled`` (0 means the
    backend default / no N-tiling) and, rounded up to 128, for ``pallas``
    block sizes.
    """
    backend: str
    tile_m: int = 0
    tile_n: int = 0
    source: str = "heuristic"          # heuristic | cache | autotune | fixed


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve a backend *name*: None -> "auto" -> ``REPRO_GRMAC_BACKEND``.

    Returns "auto" when nothing forces a concrete choice — the shape-aware
    ``plan_for`` then decides per call. Explicit names always win over the
    environment.
    """
    b = backend or "auto"
    if b == "auto":
        b = os.environ.get(_ENV_VAR, "auto") or "auto"
    if b not in BACKENDS:
        raise ValueError(
            f"unknown GR-MAC backend {b!r}; expected one of {BACKENDS}")
    return b


# --------------------------------------------------------------- plan cache
_MEM_PLANS: Dict[str, Plan] = {}
_DISK_PLANS: Optional[Dict[str, dict]] = None
_DISK_PLANS_PATH: Optional[str] = None


def plan_cache_path() -> str:
    return os.environ.get(_PLAN_CACHE_ENV) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "grmac_plans.json")


def clear_plan_cache(memory_only: bool = True) -> None:
    """Drop in-memory plans (and force a re-read of the disk cache). With
    ``memory_only=False`` also deletes the persisted JSON file."""
    global _DISK_PLANS, _DISK_PLANS_PATH
    _MEM_PLANS.clear()
    _DISK_PLANS = None
    _DISK_PLANS_PATH = None
    if not memory_only:
        try:
            os.remove(plan_cache_path())
        except OSError:
            pass


def _plan_key(m, k, n, granularity, fmt_x, fmt_w, n_r) -> str:
    return (f"{m}x{k}x{n}|{granularity}|{fmt_x.name}x{fmt_w.name}"
            f"|nr{n_r}|{jax.default_backend()}")


def _load_disk_plans() -> Dict[str, dict]:
    global _DISK_PLANS, _DISK_PLANS_PATH
    path = plan_cache_path()
    if _DISK_PLANS is None or _DISK_PLANS_PATH != path:
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            raw = None
        if raw is None:
            _DISK_PLANS = {}
        elif (not isinstance(raw, dict)
              or raw.get("version") != PLAN_CACHE_VERSION):
            # Version mismatch (including pre-versioned caches, which have
            # no "version" key): the plans may have been measured under
            # different schema rules, so ignore them — the next persisted
            # plan rewrites the file under the current version.
            warnings.warn(
                f"ignoring GR-MAC plan cache {path!r}: schema version "
                f"{raw.get('version') if isinstance(raw, dict) else '?'} "
                f"!= {PLAN_CACHE_VERSION} (stale cache; it will be "
                "rewritten on the next autotuned plan)")
            _DISK_PLANS = {}
        else:
            _DISK_PLANS = raw.get("plans", {})
        _DISK_PLANS_PATH = path
    return _DISK_PLANS


def _persist_plan(key: str, plan: Plan, warm_us: float) -> None:
    path = plan_cache_path()
    plans = dict(_load_disk_plans())
    plans[key] = {"backend": plan.backend, "tile_m": plan.tile_m,
                  "tile_n": plan.tile_n, "warm_us": warm_us}
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"version": PLAN_CACHE_VERSION, "plans": plans},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        return  # read-only filesystems just skip persistence
    global _DISK_PLANS, _DISK_PLANS_PATH
    _DISK_PLANS, _DISK_PLANS_PATH = plans, path


def _heuristic_plan(m, k, n, n_r) -> Plan:
    if jax.default_backend() == "tpu":
        return Plan("pallas", source="heuristic")
    if m >= _TILED_MIN_M:
        return Plan("tiled", tile_m=default_tile_m(k, n, n_r),
                    source="heuristic")
    return Plan("xla", source="heuristic")


def _autotune_candidates(m, k, n, n_r):
    cands = [Plan("xla", source="autotune")]
    tm0 = default_tile_m(k, n, n_r)
    for tm in sorted({max(8, tm0 // 2), tm0, min(256, tm0 * 2)}):
        cands.append(Plan("tiled", tile_m=tm, source="autotune"))
    if n >= 2048:
        cands.append(Plan("tiled", tile_m=tm0, tile_n=1024,
                          source="autotune"))
    return cands


def _run_plan(x, wq, plan: Plan, kwargs, tag: str = "") -> jax.Array:
    b = plan.backend
    if b in ("pallas", "pallas_interpret"):
        n_r = kwargs["n_r"]
        bm = max(128, -(-plan.tile_m // 128) * 128) if plan.tile_m else 128
        bn = max(128, -(-plan.tile_n // 128) * 128) if plan.tile_n else 128
        bk = math.lcm(128, n_r)
        m, n = x.shape[0], wq.shape[1]
        xp = pad_to_multiple(pad_to_multiple(x, 0, bm), 1, bk)
        wp = pad_to_multiple(pad_to_multiple(wq, 0, bk), 1, bn)
        out = grmac_matmul_pallas(
            xp, wp, block_m=bm, block_n=bn, block_k=bk,
            interpret=(True if b == "pallas_interpret" else None), **kwargs)
        return out[:m, :n]

    bf16 = os.environ.get(_BF16_ENV, "0") not in ("", "0")
    san = os.environ.get(_SAN_ENV, "0") not in ("", "0")
    # tag="" when the sanitizer is off: the site label is only consumed by
    # sanitize reports, and keeping it constant avoids one jit cache entry
    # per call site in the normal (uninstrumented) regime.
    san_kw = dict(sanitize=san, tag=(tag if san else ""))
    xp = pad_to_multiple(x, 1, kwargs["n_r"])
    wp = pad_to_multiple(wq, 0, kwargs["n_r"])
    if b == "tiled":
        return grmac_matmul_tiled(xp, wp, tile_m=plan.tile_m,
                                  tile_n=plan.tile_n, bf16_values=bf16,
                                  **san_kw, **kwargs)
    if b == "xla":
        return grmac_matmul_xla(xp, wp, bf16_values=bf16, **san_kw, **kwargs)
    return grmac_matmul_ref(xp, wp, **san_kw, **kwargs)


def _probe(key, m, k, n, granularity, fmt_x, fmt_w, n_r, enob) -> Plan:
    """Measure the candidate plans once on synthetic operands and persist
    the winner. Data-independent: only shapes matter, so the probe never
    needs (or touches) the caller's arrays."""
    from repro.core.formats import quantize  # local: avoid cycle at import

    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.uniform(kx, (m, k), minval=-1.0, maxval=1.0)
    wq = quantize(jax.random.uniform(kw, (k, n), minval=-1.0, maxval=1.0),
                  fmt_w)
    kwargs = dict(fmt_x=fmt_x, fmt_w=fmt_w, n_r=n_r, enob=enob,
                  granularity=granularity)
    best, best_us = None, float("inf")
    for cand in _autotune_candidates(m, k, n, n_r):
        try:
            jax.block_until_ready(_run_plan(x, wq, cand, kwargs))  # compile
            times = []
            for _ in range(2):
                t0 = time.perf_counter()
                jax.block_until_ready(_run_plan(x, wq, cand, kwargs))
                times.append(time.perf_counter() - t0)
            us = min(times) * 1e6
        except Exception:
            continue
        if us < best_us:
            best, best_us = cand, us
    if best is None:
        return _heuristic_plan(m, k, n, n_r)
    _persist_plan(key, best, best_us)
    return best


def _tracing() -> bool:
    try:
        return not jax.core.trace_state_clean()
    except AttributeError:
        # jax without trace_state_clean: we cannot tell, and probing inside
        # a trace would stage timing runs into the caller's graph — the safe
        # degradation is to skip probing (heuristic/cache still apply).
        return True


def plan_for(
    m: int,
    k: int,
    n: int,
    *,
    granularity: str = "row",
    fmt_x: FPFormat,
    fmt_w: FPFormat,
    n_r: int = 32,
    enob: float = 8.0,
    backend: Optional[str] = None,
) -> Plan:
    """Shape-aware dispatch plan (see module docstring for the lookup
    order). Explicit backend names short-circuit to a fixed plan."""
    b = resolve_backend(backend)
    if b != "auto":
        return Plan(b, source="fixed")
    key = _plan_key(m, k, n, granularity, fmt_x, fmt_w, n_r)
    hit = _MEM_PLANS.get(key)
    if hit is not None:
        return hit
    rec = _load_disk_plans().get(key)
    # "auto" is a planner input, never a valid planned backend: a corrupt
    # or version-skewed cache entry must not fall through to the oracle
    if (rec is not None and rec.get("backend") in BACKENDS
            and rec["backend"] != "auto"):
        plan = Plan(rec["backend"], int(rec.get("tile_m", 0)),
                    int(rec.get("tile_n", 0)), source="cache")
        _MEM_PLANS[key] = plan
        return plan
    if (os.environ.get(_AUTOTUNE_ENV, "0") not in ("", "0")
            and not _tracing()):
        plan = _probe(key, m, k, n, granularity, fmt_x, fmt_w, n_r, enob)
        _MEM_PLANS[key] = plan
        return plan
    # heuristic answers are NOT memoized into _MEM_PLANS: a later call with
    # autotune enabled (or a freshly persisted plan) must still win.
    return _heuristic_plan(m, k, n, n_r)


def grmac_matmul(
    x: jax.Array,
    wq: jax.Array,
    *,
    fmt_x: FPFormat,
    fmt_w: FPFormat,
    n_r: int = 32,
    enob: float = 8.0,
    granularity: str = "row",
    backend: Optional[str] = None,
    tile_m: Optional[int] = None,
    tile_n: Optional[int] = None,
    tag: str = "",
) -> jax.Array:
    """(M, K) @ (K, N) GR-MAC matmul via the planned backend.

    ``x`` pre-scaled to [-1, 1]; ``wq`` already on the weight format grid.
    Arbitrary M/N/K (padding handled here); float32 output. ``tile_m`` /
    ``tile_n`` override the plan's tile sizes (``tiled``/``pallas`` only).
    ``tag`` names the call site in ``REPRO_SANITIZE=1`` violation reports
    (metadata only; never changes numerics or planning).
    """
    m, k = x.shape
    n = wq.shape[1]
    plan = plan_for(m, k, n, granularity=granularity, fmt_x=fmt_x,
                    fmt_w=fmt_w, n_r=n_r, enob=enob, backend=backend)
    if tile_m is not None or tile_n is not None:
        plan = dataclasses.replace(
            plan,
            tile_m=plan.tile_m if tile_m is None else tile_m,
            tile_n=plan.tile_n if tile_n is None else tile_n)
    kwargs = dict(fmt_x=fmt_x, fmt_w=fmt_w, n_r=n_r, enob=enob,
                  granularity=granularity)
    return _run_plan(x, wq, plan, kwargs, tag=tag)
