"""Training stack: optimizer, distributed train step, and the resilience
loop that keeps a fleet's wall clock productive.

Five modules, one seam
----------------------
* ``optimizer``  — AdamW / Adafactor with schedule, plus int8
  error-feedback gradient compression for the DP all-reduce.
* ``trainer``    — ``make_train_step`` (microbatch ``lax.scan``
  accumulation, f32 grad accumulation) and the resilient ``train``
  driver (restore-or-init, heartbeats, async checkpoints).
* ``checkpoint`` — atomic, content-verified checkpoints and the
  two-tier ``AsyncCheckpointer``. **Tiers**: *local* (node-local SSD —
  fast, written every k steps, lost with the node) and *durable*
  (object store / NFS — slower, every K steps, survives node loss).
  Writes are tmp-dir + atomic-rename with a sha256 leaf manifest, done
  by a background thread off a host snapshot, so a crash can never
  publish a torn step and the training thread only pays the
  ``device_get``. Restore walks tiers freshest-first and falls back
  past corrupt steps with a UserWarning.
* ``fault``      — detection and planning primitives. **Fault
  taxonomy** (``FAULT_KINDS``): *kill* (process dies, node survives —
  local tier available), *device_loss* (chips and their node-local
  tier gone — durable restore + ``plan_remesh`` shrinks the DP width,
  ``reshard_tree`` places the state), *straggler* (step time degrades
  ``severity``× — detected against the fleet median, no restart).
  ``FaultPlan`` is the injection side: a seeded, step-ordered schedule.
* ``supervisor`` — the closed loop. Runs training under a
  ``FaultPlan``: inject → detect (``HeartbeatBoard`` +
  ``detect_failures`` / ``detect_stragglers``) → restore from the
  freshest tier → (elastic) resume, with every wall second bucketed.

GoodPut definitions
-------------------
``GoodPutLedger`` partitions wall time — each instant belongs to
exactly one bucket: *productive* (first-time steps — the only GoodPut),
*recompute* (re-running steps lost to a restart), *checkpoint_stall*
(training-thread snapshot+enqueue and fault-boundary drains),
*detection*, *recovery* (restore + re-shard), *overhead*.
``goodput_pct = 100 × productive / wall``; bucket times provably sum to
the wall clock. ``price_drill`` prices the BadPut through the
CostLedger: pJ-per-useful-token = pJ/token × tokens_computed /
tokens_useful.

Drill determinism
-----------------
Faults fire at scheduled steps of a deterministic loop; the simulated
fleet heartbeats on a virtual clock (1.0 per step) so detection takes a
machine-independent number of rounds; the async writer drains at every
fault boundary so per-tier checkpoint counts cannot race the fault.
Every drill counter is therefore a pure function of (arch, plan,
config) — ``benchmarks/goodput_bench.py`` exact-gates them in CI. The
(seed, step)-pure data pipeline plus the exact host roundtrip of the
checkpoint format make the resumed loss trajectory *bit-identical* to
an uninterrupted run, asserted inline on every recomputed step.

Benchmarks: ``benchmarks/goodput_bench.py`` (supervised fault drill:
GoodPut %, detection/recovery counters, pJ-per-useful-token).
Tests: ``tests/test_supervisor.py`` (torn-checkpoint crash drills,
ledger partition property, drill end-to-end), ``tests/test_training.py``.
"""
from repro.training.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.fault import (
    FAULT_KINDS,
    FaultEvent,
    FaultPlan,
    HeartbeatBoard,
    detect_failures,
    detect_stragglers,
    make_fault_plan,
    plan_remesh,
)
from repro.training.optimizer import OptimizerConfig
from repro.training.supervisor import (
    DrillConfig,
    GoodPutLedger,
    Supervisor,
    price_drill,
)
from repro.training.trainer import TrainConfig, make_train_step, train

__all__ = [
    "AsyncCheckpointer", "latest_step", "restore_checkpoint",
    "save_checkpoint",
    "FAULT_KINDS", "FaultEvent", "FaultPlan", "HeartbeatBoard",
    "detect_failures", "detect_stragglers", "make_fault_plan",
    "plan_remesh",
    "OptimizerConfig",
    "DrillConfig", "GoodPutLedger", "Supervisor", "price_drill",
    "TrainConfig", "make_train_step", "train",
]
