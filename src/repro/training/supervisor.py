"""Supervised fault drills: deterministic failure injection, detection,
recovery, and measured GoodPut for the training loop.

The paper's pJ/token bounds only matter at fleet scale if the fleet is
doing *useful* work: every restart-and-recompute burns energy on tokens
that are thrown away. This module closes the resilience loop around the
scaffolding the training package already ships — ``fault.py``'s
heartbeats/detectors/remesh planner and ``checkpoint.py``'s atomic
two-tier async writer — and measures the result as GoodPut %, plus
pJ-per-*useful*-token through the CostLedger.

Drill anatomy
-------------
``Supervisor.run_drill`` executes a training run under a seeded
``FaultPlan`` (see ``fault.FAULT_KINDS``):

* **kill** — the training process dies at a step boundary. Its host
  stops heartbeating; the supervisor detects it via
  ``detect_failures`` over the (simulated) fleet board, restarts, and
  restores the freshest checkpoint across tiers (the node survived, so
  the fast **local** tier is available — minimal recompute).
* **device_loss** — a worker host's chips drop out permanently. The
  node-local checkpoint tier is lost with it
  (``AsyncCheckpointer.invalidate_local``), so restore falls back to
  the older **durable** tier (more recompute), and the run resumes
  *elastically*: ``plan_remesh`` shrinks the data-parallel width to the
  surviving chips, ``parallel.sharding.param_specs`` lays the restored
  state out for the new mesh, and ``reshard_tree`` places it.
* **straggler** — a host's step time degrades by ``severity``×; no
  restart. The supervisor detects it via ``detect_stragglers`` against
  the fleet median and logs the mitigation decision.

Determinism contract (what the goodput bench exact-gates)
---------------------------------------------------------
Faults fire at *scheduled steps* of a deterministic loop; the fleet
board runs on a virtual clock (1.0 per step) so detection happens after
a machine-independent number of monitoring rounds; the async writer is
drained at each fault boundary so checkpoint counts cannot race the
fault. Hence faults injected/detected, checkpoints per tier, restores
per tier, steps recomputed, and the final step are pure functions of
(arch, plan, config) — any drift is a behavior change, not noise. The
(seed, step)-pure data pipeline plus the exact host-roundtrip of the
checkpoint format make the *resumed loss trajectory bit-identical* to an
uninterrupted run at matching steps, which ``run_drill`` asserts inline
whenever it recomputes a step it has seen before.

GoodPut definitions
-------------------
``GoodPutLedger`` partitions wall time — every instant between
``start()`` and ``close()`` belongs to exactly one bucket:

* ``productive``       — first-time training steps (the only GoodPut);
* ``recompute``        — re-running steps lost to a restart (BadPut);
* ``checkpoint_stall`` — training-thread time inside snapshot+enqueue
  (the async writer's residual synchronous cost) and fault-boundary
  drains;
* ``detection``        — monitoring rounds until a fault is confirmed;
* ``recovery``         — restore + elastic re-shard + restart;
* ``overhead``         — everything else (init, bookkeeping).

``goodput_pct = 100 × productive / wall``. ``price_drill`` extends the
energy story: pJ-per-useful-token =
pJ/token × tokens_computed / tokens_useful, where recomputed steps
inflate tokens_computed but never tokens_useful.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.compat import make_mesh
from repro.configs.base import ArchConfig
from repro.models import init_params
from repro.parallel.sharding import param_specs
from repro.training import checkpoint as ckpt
from repro.training.fault import (
    FaultPlan,
    Heartbeat,
    HeartbeatBoard,
    detect_failures,
    detect_stragglers,
    plan_remesh,
    reshard_tree,
)
from repro.training.optimizer import init_opt_state
from repro.training.trainer import TrainConfig, make_train_step

__all__ = ["DrillConfig", "GoodPutLedger", "SimFleet", "Supervisor",
           "price_drill"]


# ---------------------------------------------------------------- ledger
class GoodPutLedger:
    """Wall-time partition + deterministic counters (module docstring).

    The timeline is a strict partition: exactly one bucket is current at
    any instant, ``to``/``in_bucket`` switch it, and ``close`` flushes
    the tail — so the bucket times sum to the total wall clock
    (property-tested). ``clock`` is injectable for deterministic
    tests."""

    BUCKETS = ("productive", "recompute", "checkpoint_stall",
               "detection", "recovery", "overhead")

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self.buckets: Dict[str, float] = {b: 0.0 for b in self.BUCKETS}
        self.counters: Dict[str, int] = {}
        self._cur = "overhead"
        self._t0: Optional[float] = None
        self._t_mark: Optional[float] = None
        self._wall: Optional[float] = None

    def start(self) -> "GoodPutLedger":
        self._t0 = self._t_mark = self._clock()
        return self

    def to(self, bucket: str) -> str:
        """Switch the current bucket; returns the previous one."""
        if bucket not in self.buckets:
            raise KeyError(f"unknown bucket {bucket!r}")
        if self._t_mark is None:
            raise RuntimeError("GoodPutLedger.start() was never called")
        now = self._clock()
        self.buckets[self._cur] += now - self._t_mark
        self._t_mark = now
        prev, self._cur = self._cur, bucket
        return prev

    @contextmanager
    def in_bucket(self, bucket: str):
        prev = self.to(bucket)
        try:
            yield self
        finally:
            self.to(prev)

    def inc(self, counter: str, n: int = 1) -> None:
        self.counters[counter] = self.counters.get(counter, 0) + n

    def close(self) -> float:
        if self._wall is None:
            self.to(self._cur)           # flush the tail interval
            self._wall = self._t_mark - self._t0
        return self._wall

    @property
    def wall_s(self) -> float:
        if self._wall is not None:
            return self._wall
        return self._clock() - self._t0

    @property
    def goodput_frac(self) -> float:
        return self.buckets["productive"] / max(self.wall_s, 1e-12)

    def report(self) -> dict:
        return {
            "wall_s": self.wall_s,
            "goodput_pct": 100.0 * self.goodput_frac,
            "buckets_s": dict(self.buckets),
            "counters": dict(self.counters),
        }


# ----------------------------------------------------------------- fleet
class SimFleet:
    """Deterministic simulated fleet around the single real process.

    Host 0 is the (real) trainer; hosts 1..n-1 are synthetic peers that
    beat nominal step times. The fleet clock is *virtual* — 1.0 per
    training step, advanced explicitly — so failure detection
    (``detect_failures`` with ``timeout_s`` in virtual units) completes
    after a machine-independent number of monitoring rounds. During a
    detection loop the surviving hosts keep beating (their processes
    are alive; it is the collective op that hangs), so only genuinely
    dead hosts age out."""

    def __init__(self, board: HeartbeatBoard, n_hosts: int,
                 chips_per_host: int, timeout_s: float = 3.0):
        self.board = board
        self.chips_per_host = chips_per_host
        self.timeout_s = timeout_s
        self.healthy = set(range(n_hosts))
        self.t = 0.0
        self.last_step = 0

    @property
    def n_chips(self) -> int:
        return len(self.healthy) * self.chips_per_host

    def beat_all(self, step: int,
                 step_times: Optional[Dict[int, float]] = None) -> None:
        st = step_times or {}
        for h in self.healthy:
            self.board.beat(Heartbeat(h, step, self.t, st.get(h, 1.0)))
        self.last_step = step

    def tick(self, dt: float = 1.0) -> None:
        self.t += dt

    def kill(self, host: int) -> None:
        self.healthy.discard(host)

    def revive(self, host: int) -> None:
        self.healthy.add(host)

    def decommission(self, host: int) -> None:
        self.board.clear(host)

    def detect_dead(self) -> List[int]:
        """Monitoring rounds until ``detect_failures`` reports someone:
        survivors re-beat each round, the dead age past ``timeout_s`` on
        the virtual clock. Deterministic: fires after
        ``floor(timeout_s) + 1`` rounds."""
        deadline = self.t + 10.0 * (self.timeout_s + 1.0)
        while self.t < deadline:
            self.tick()
            for h in self.healthy:
                self.board.beat(Heartbeat(h, self.last_step, self.t, 1.0))
            dead = detect_failures(self.board.read_all(), self.t,
                                   timeout_s=self.timeout_s)
            if dead:
                return dead
        raise RuntimeError("injected failure was never detected")


# ------------------------------------------------------------ supervisor
@dataclasses.dataclass(frozen=True)
class DrillConfig:
    """Drill parameters. ``workdir`` roots the checkpoint tiers
    (``local/``, ``durable/``) and the heartbeat board; tier cadences
    are ``local_every`` (k) / ``durable_every`` (K)."""
    workdir: str
    steps: int = 12
    local_every: int = 2
    durable_every: int = 6
    keep_local: int = 2
    keep_durable: int = 3
    n_hosts: int = 4
    n_chips: int = 8
    model_parallel: int = 1
    pod_size: int = 256
    heartbeat_timeout: float = 3.0
    straggler_factor: float = 2.0

    @property
    def local_dir(self) -> str:
        return os.path.join(self.workdir, "local")

    @property
    def durable_dir(self) -> str:
        return os.path.join(self.workdir, "durable")

    @property
    def heartbeat_dir(self) -> str:
        return os.path.join(self.workdir, "heartbeats")


class Supervisor:
    """Runs training under a ``FaultPlan`` and closes the loop:
    inject → detect → restore-from-freshest-tier → (elastic) resume,
    with every wall second bucketed in a ``GoodPutLedger``."""

    def __init__(self, arch: ArchConfig, tcfg: TrainConfig,
                 dcfg: DrillConfig, pipeline, plan: FaultPlan, *,
                 seed: int = 0):
        if dcfg.n_chips % dcfg.n_hosts != 0:
            raise ValueError("n_chips must divide evenly over n_hosts")
        self.arch, self.tcfg, self.dcfg = arch, tcfg, dcfg
        self.pipeline, self.plan, self.seed = pipeline, plan, seed

    # The physical mesh spans whatever devices this process actually has
    # (1×1 on the CPU container); the *logical* dp width from
    # ``plan_remesh`` is tracked in the report. On a real cluster the two
    # coincide and ``reshard_tree`` moves bytes between chips.
    def _physical_mesh(self):
        return make_mesh((len(jax.devices()), 1), ("data", "model"))

    def _dp_width(self, n_chips: int) -> int:
        shape, axes = plan_remesh(n_chips, self.dcfg.model_parallel,
                                  self.dcfg.pod_size)
        return int(np.prod([s for s, a in zip(shape, axes)
                            if a != "model"]))

    def run_drill(self) -> dict:
        dcfg, tcfg = self.dcfg, self.tcfg
        led = GoodPutLedger().start()
        board = HeartbeatBoard(dcfg.heartbeat_dir)
        fleet = SimFleet(board, dcfg.n_hosts,
                         dcfg.n_chips // dcfg.n_hosts,
                         timeout_s=dcfg.heartbeat_timeout)
        writer = ckpt.AsyncCheckpointer(
            dcfg.durable_dir, dcfg.local_dir,
            durable_every=dcfg.durable_every, local_every=dcfg.local_every,
            keep_durable=dcfg.keep_durable, keep_local=dcfg.keep_local)

        params = init_params(jax.random.PRNGKey(self.seed), self.arch)
        state = {"params": params,
                 "opt": init_opt_state(params, tcfg.opt)}
        step_fn = jax.jit(make_train_step(self.arch, tcfg))
        mesh = self._physical_mesh()
        dp_initial = self._dp_width(fleet.n_chips)
        dp_width = dp_initial

        # a durable floor: a fault scheduled before the first cadence save
        # must still have something to recover to (restoring step 0 just
        # recomputes the run from its deterministic init)
        with led.in_bucket("checkpoint_stall"):
            writer.save(0, state, ("durable",))

        events = deque(self.plan.events)
        losses: Dict[int, float] = {}
        high_water = 0     # furthest step ever completed (+1)
        start = 0
        attempts = 0

        while True:
            attempts += 1
            aborted = None
            for step in range(start, dcfg.steps):
                ev = events[0] if events else None
                if ev and ev.step == step and ev.kind in ("kill",
                                                          "device_loss"):
                    events.popleft()
                    led.inc("faults_injected")
                    led.inc(f"fault_{ev.kind}")
                    # drill determinism: quiesce the writer at the fault
                    # boundary so per-tier checkpoint counts cannot race
                    # the fault (torn-write behavior is unit-tested
                    # separately, not measured here)
                    with led.in_bucket("checkpoint_stall"):
                        writer.drain()
                    aborted = ev
                    break

                recompute = step < high_water
                if recompute:
                    led.inc("steps_recomputed")
                with led.in_bucket("recompute" if recompute
                                   else "productive"):
                    batch = self.pipeline.batch_at(step)
                    p, o, m = step_fn(state["params"], state["opt"], batch)
                    jax.block_until_ready(m["loss"])
                state = {"params": p, "opt": o}
                loss = float(m["loss"])
                if step in losses and losses[step] != loss:
                    raise AssertionError(
                        f"recomputed step {step} diverged from its first "
                        f"run: {losses[step]!r} vs {loss!r} — the "
                        "(seed, step)-pure resume contract is broken")
                losses[step] = loss

                step_times = {0: 1.0}
                if ev and ev.step == step and ev.kind == "straggler":
                    events.popleft()
                    led.inc("faults_injected")
                    led.inc("fault_straggler")
                    # the trainer reports a severity×-degraded step time;
                    # detection is against the fleet median
                    step_times[0] = float(ev.severity)
                    with led.in_bucket("detection"):
                        fleet.beat_all(step, step_times)
                        slow = detect_stragglers(
                            board.read_all(),
                            factor=dcfg.straggler_factor)
                        if 0 in slow:
                            led.inc("faults_detected")
                            led.inc("stragglers_detected")
                else:
                    fleet.beat_all(step, step_times)
                fleet.tick()
                high_water = max(high_water, step + 1)
                with led.in_bucket("checkpoint_stall"):
                    writer.maybe_save(step + 1, state)

            if aborted is None:
                break   # drill complete

            # ---------------- failure handling: detect, then recover
            if aborted.kind == "kill":
                killed = [0]
            else:
                # device loss takes out the highest-numbered survivors
                survivors = sorted(h for h in fleet.healthy if h != 0)
                killed = survivors[-aborted.severity:]
            for h in killed:
                fleet.kill(h)
            with led.in_bucket("detection"):
                dead = fleet.detect_dead()
                if set(killed) <= set(dead):
                    led.inc("faults_detected")

            with led.in_bucket("recovery"):
                if aborted.kind == "device_loss":
                    for h in killed:
                        fleet.decommission(h)
                    # the node-local SSD tier died with the node
                    writer.invalidate_local()
                    dp_width = self._dp_width(fleet.n_chips)
                    led.inc("remesh_events")
                    include_local = False
                else:
                    fleet.revive(0)   # the killed trainer restarts
                    include_local = True
                state_np, rstep, tier = writer.restore(
                    state, include_local=include_local)
                led.inc(f"restore_{tier}")
                specs = {k: param_specs(state_np[k], mesh)
                         for k in state_np}
                state = reshard_tree(state_np, mesh, specs)
                start = rstep

        with led.in_bucket("checkpoint_stall"):
            writer.save(dcfg.steps, state, ("durable",))
            writer.close()
        led.close()

        c = led.counters.get
        return {
            "final_step": high_water,
            "attempts": attempts,
            "faults_injected": c("faults_injected", 0),
            "faults_detected": c("faults_detected", 0),
            "fault_kill": c("fault_kill", 0),
            "fault_device_loss": c("fault_device_loss", 0),
            "fault_straggler": c("fault_straggler", 0),
            "steps_recomputed": c("steps_recomputed", 0),
            "ckpt_local": writer.stats["local"],
            "ckpt_durable": writer.stats["durable"],
            "restore_local": c("restore_local", 0),
            "restore_durable": c("restore_durable", 0),
            "remesh_events": c("remesh_events", 0),
            "dp_width_initial": dp_initial,
            "dp_width_final": dp_width,
            "losses": [losses[s] for s in range(dcfg.steps)],
            "goodput": led.report(),
        }


# ----------------------------------------------------------------- energy
def price_drill(arch: ArchConfig, report: dict, *, tokens_per_step: int,
                seed: int = 0, n_cols: int = 1 << 8) -> dict:
    """Price a drill's BadPut through the CostLedger: recomputed steps
    inflate the tokens *computed* (and their energy) but never the
    tokens *usefully trained on*, so
    ``pj_per_useful_token = pj_per_token × computed / useful``. The
    per-token figure comes from the shape-only train trace of the arch's
    CIM deployment (``grmac`` mode when the arch serves digital), as in
    the serving benches."""
    from repro.core import costs

    cim_arch = arch if arch.cim.enabled else arch.replace(
        cim=arch.cim.with_mode("grmac"))
    ledger = costs.trace_train(cim_arch)
    trace_tokens = costs.default_train_seq(cim_arch)
    pj_tok = costs.price_ledger(ledger, trace_tokens,
                                seed=seed, n_cols=n_cols)["pj_per_token"]
    useful = report["final_step"] * tokens_per_step
    computed = (report["final_step"]
                + report["steps_recomputed"]) * tokens_per_step
    return {
        "tokens_useful": useful,
        "tokens_computed": computed,
        "pj_per_token": pj_tok,
        "pj_per_useful_token": pj_tok * computed / max(useful, 1),
        "badput_energy_overhead": computed / max(useful, 1),
    }
