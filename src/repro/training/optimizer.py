"""AdamW with warmup-cosine schedule, global-norm clipping, and an optional
int8 error-feedback gradient-compression hook for the DP all-reduce.

Pure-pytree implementation (no optax dependency): state = {m, v, count,
[err]} mirroring the param tree, so parameter sharding specs apply to the
optimizer state unchanged — the property that matters at 512+ ways.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "init_opt_state", "apply_updates", "schedule",
           "compress_grads", "decompress_grads"]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compression: bool = False     # int8 error-feedback DP compression
    # "adamw": m+v f32 (8 B/param). "adafactor": factored second moment
    # (~0 B/param for matrices) — the memory-side lever for 100B+ models.
    algorithm: str = "adamw"


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params, cfg: OptimizerConfig):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    if cfg.algorithm == "adafactor":
        def vrow(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2
                    else jnp.zeros(p.shape, jnp.float32))

        def vcol(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if p.ndim >= 2 else jnp.zeros((1,), jnp.float32))

        state = {
            "vr": jax.tree.map(vrow, params),
            "vc": jax.tree.map(vcol, params),
            "count": jnp.zeros((), jnp.int32),
        }
    else:
        state = {
            "m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
            "count": jnp.zeros((), jnp.int32),
        }
    if cfg.grad_compression:
        state["err"] = jax.tree.map(zeros32, params)
    return state


# ---------------------------------------------------------- compression
def compress_grads(grads, err):
    """int8 per-tensor scaled quantization with error feedback.

    Returns (int8 tree, scales tree, new residuals). The all-reduce then
    moves 4x fewer bytes; residuals re-enter next step (convergence-safe).
    """
    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q, scale, g - q.astype(jnp.float32) * scale

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(err)
    qs, scales, errs = zip(*[one(g, e) for g, e in zip(flat, eflat)])
    unf = lambda xs: jax.tree.unflatten(treedef, list(xs))
    return unf(qs), unf(scales), unf(errs)


def decompress_grads(q, scales):
    return jax.tree.map(
        lambda qq, s: qq.astype(jnp.float32) * s, q, scales)


# ---------------------------------------------------------- update
def apply_updates(
    params, grads, state, cfg: OptimizerConfig
) -> Tuple[dict, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule(cfg, count)

    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    if cfg.algorithm == "adafactor":
        return _apply_adafactor(params, grads, state, cfg, count, lr, clip,
                                gnorm)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** count.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** count.astype(jnp.float32))
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step_
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda t: isinstance(t, tuple))
    newp = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    newm = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    newv = jax.tree.unflatten(treedef, [l[2] for l in leaves])
    new_state = dict(state, m=newm, v=newv, count=count)
    return newp, new_state, {"grad_norm": gnorm, "lr": lr}


def _apply_adafactor(params, grads, state, cfg, count, lr, clip, gnorm):
    """Adafactor (Shazeer & Stern): rank-1 factored second moment for
    matrices, no first moment — ~0 optimizer bytes per parameter."""
    b2 = 1.0 - count.astype(jnp.float32) ** -0.8  # step-dependent decay
    eps = 1e-30

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32) * clip
        g2 = jnp.square(g) + eps
        if p.ndim >= 2:
            vr_n = b2 * vr + (1 - b2) * jnp.mean(g2, axis=-1)
            vc_n = b2 * vc + (1 - b2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(
                jnp.mean(vr_n, axis=-1, keepdims=True), eps)
            vhat = (vr_n[..., None] * vc_n[..., None, :]) / denom[..., None]
            step_ = g / jnp.sqrt(vhat + eps)
        else:
            vr_n = b2 * vr + (1 - b2) * g2
            vc_n = vc
            step_ = g / jnp.sqrt(vr_n + eps)
        # update clipping (RMS <= 1) per the paper
        rms = jnp.sqrt(jnp.mean(jnp.square(step_)) + eps)
        step_ = step_ / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step_
        return newp.astype(p.dtype), vr_n, vc_n

    out = jax.tree.map(upd, params, grads, state["vr"], state["vc"])
    leaves, treedef = jax.tree.flatten(
        out, is_leaf=lambda t: isinstance(t, tuple))
    newp = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    newr = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    newc = jax.tree.unflatten(treedef, [l[2] for l in leaves])
    new_state = dict(state, vr=newr, vc=newc, count=count)
    return newp, new_state, {"grad_norm": gnorm, "lr": lr}
