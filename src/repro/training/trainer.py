"""Distributed train-step construction and the resilient training loop.

``make_train_step`` builds a pjit-able (params, opt_state, batch) ->
(params, opt_state, metrics) function with:

* microbatch gradient accumulation (sequential ``lax.scan`` over microbatch
  splits — activation memory / global-batch decoupling),
* optional int8 error-feedback gradient compression before the DP
  all-reduce boundary (OptimizerConfig.grad_compression),
* f32 gradient accumulation regardless of param dtype.

``train`` is the driver: restore-or-init, heartbeats, periodic atomic
checkpoints, straggler logging — everything the multi-pod launcher uses.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import init_params, train_loss
from repro.training import checkpoint as ckpt
from repro.training.fault import Heartbeat, HeartbeatBoard
from repro.training.optimizer import (
    OptimizerConfig,
    apply_updates,
    compress_grads,
    decompress_grads,
    init_opt_state,
)

__all__ = ["TrainConfig", "make_train_step", "train"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1
    # durable checkpoint tier (object store / NFS in production). Written
    # asynchronously every ckpt_every steps by a background writer — the
    # training thread only pays the device_get snapshot.
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    # optional fast local tier (node-local SSD: lost with the node but
    # cheap to write often). None disables the tier.
    ckpt_local_dir: Optional[str] = None
    ckpt_local_every: int = 10
    log_every: int = 10
    # GR-MAC backend override for CIM-enabled archs (None keeps the arch's
    # CIMConfig.backend; see kernels.dispatch for the choices). Training
    # batches are large-M matmuls, so "auto" plans onto the fused tiled
    # backend; cim_tile_m / cim_tile_n pin its tile sizes when set.
    cim_backend: Optional[str] = None
    cim_tile_m: Optional[int] = None
    cim_tile_n: Optional[int] = None
    opt: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)


def make_train_step(arch: ArchConfig, tcfg: TrainConfig) -> Callable:
    if tcfg.cim_backend is not None:
        arch = arch.replace(cim=arch.cim.with_backend(tcfg.cim_backend))
    if tcfg.cim_tile_m is not None or tcfg.cim_tile_n is not None:
        arch = arch.replace(cim=arch.cim.with_tiles(
            tcfg.cim_tile_m, tcfg.cim_tile_n))
    ocfg = tcfg.opt
    nmb = tcfg.microbatches

    def loss_fn(params, mb):
        total, metrics = train_loss(params, mb, arch)
        return total, metrics

    def train_step(params, opt_state, batch):
        if nmb == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(nmb, b // nmb, *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"loss": jnp.zeros(()), "aux_loss": jnp.zeros(()),
                      "total": jnp.zeros(())}

            def acc_fn(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32) / nmb, g_acc, g)
                # accumulate the WHOLE metrics dict: MoE aux losses must
                # survive microbatching, not read as 0 in the logs
                m_acc = jax.tree.map(lambda a, m: a + m / nmb, m_acc, metrics)
                return (g_acc, m_acc), None

            (grads, metrics), _ = jax.lax.scan(acc_fn, (zero, zero_m), mbs)

        if ocfg.grad_compression:
            q, scales, err = compress_grads(grads, opt_state["err"])
            grads = decompress_grads(q, scales)   # DP all-reduce moves int8
            opt_state = dict(opt_state, err=err)

        params, opt_state, om = apply_updates(params, grads, opt_state, ocfg)
        metrics = dict(metrics, **om)
        return params, opt_state, metrics

    return train_step


def train(
    arch: ArchConfig,
    tcfg: TrainConfig,
    pipeline,
    *,
    seed: int = 0,
    heartbeat_dir: Optional[str] = None,
    jit_kwargs: Optional[dict] = None,
) -> dict:
    """Run (or resume) training; returns final metrics.

    Checkpointing is asynchronous and (optionally) two-tier: a background
    writer thread publishes atomic snapshots while training proceeds —
    the loop only pays the host snapshot (see
    ``checkpoint.AsyncCheckpointer``). Resume picks the freshest valid
    step across tiers, falling back past corrupt ones."""
    params = init_params(jax.random.PRNGKey(seed), arch)
    opt_state = init_opt_state(params, tcfg.opt)
    start_step = 0

    writer = None
    if tcfg.ckpt_dir:
        writer = ckpt.AsyncCheckpointer(
            tcfg.ckpt_dir, tcfg.ckpt_local_dir,
            durable_every=tcfg.ckpt_every,
            local_every=tcfg.ckpt_local_every)
        try:
            state, start_step, tier = writer.restore(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"[train] resumed from step {start_step} ({tier} tier)")
        except FileNotFoundError:
            pass  # cold start

    step_fn = jax.jit(make_train_step(arch, tcfg), **(jit_kwargs or {}))
    board = HeartbeatBoard(heartbeat_dir) if heartbeat_dir else None

    metrics = {}
    try:
        for step in range(start_step, tcfg.steps):
            t0 = time.time()
            batch = pipeline.batch_at(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            if board:
                board.beat(
                    Heartbeat(jax.process_index(), step, time.time(), dt))
            if step % tcfg.log_every == 0:
                print(f"[train] step {step} loss "
                      f"{float(metrics['loss']):.4f} ({dt*1e3:.0f} ms)")
            if writer:
                writer.maybe_save(step + 1,
                                  {"params": params, "opt": opt_state})
        if writer:
            writer.save(tcfg.steps, {"params": params, "opt": opt_state})
    finally:
        if writer:
            writer.close()
    return {k: float(v) for k, v in metrics.items()}
