"""Fault-tolerant checkpointing: atomic, content-verified, two-tier, async.

Layout (one directory per step):

    <dir>/step_000123.tmp/...      (written first)
    <dir>/step_000123/             (atomic rename on completion)
        meta.json                  step, config hash, leaf manifest+sha256
        arr_000.npy ...            one file per pytree leaf

Restore picks the newest *complete* step (meta.json present and every leaf
hash verifies), so a crash mid-write can never be loaded; a published step
that later fails hash or leaf-presence verification (bit rot, a lost leaf
file) is *skipped with a UserWarning* and restore falls back to the
next-newest valid step instead of hard-failing the whole job. ``keep``
bounds disk. Multi-host: each host writes only the shards it owns
(``process_index`` prefix) — on this single-process container that
degenerates to one writer, but the path layout is the multi-host one.

Two-tier async writes
---------------------
``AsyncCheckpointer`` is the production writer: a **local** tier (fast
medium, written every ``local_every`` steps, tight retention — the
node-local SSD of a real deployment, lost with the node) and a
**durable** tier (slower medium, every ``durable_every`` steps — object
store / NFS, survives node loss). ``maybe_save`` snapshots the tree on
the calling (training) thread — a single batched ``jax.device_get`` plus
an enqueue, the only part that stalls training, accumulated in
``stats["stall_s"]`` — and one background worker thread does the file
writes and the atomic rename, so training proceeds while bytes land on
disk. ``restore`` walks the tiers freshest-step-first (local wins ties),
reusing the per-directory fallback, so a torn or invalidated local tier
degrades to the durable one instead of failing.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
import time
import warnings
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "save_checkpoint", "restore_checkpoint", "latest_step",
    "snapshot_tree", "write_snapshot", "AsyncCheckpointer",
]


def _leaf_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [
        ("/".join(str(getattr(k, "key", k)) for k in path), leaf)
        for path, leaf in flat
    ]


def snapshot_tree(tree: Any) -> List[Tuple[str, np.ndarray]]:
    """Host-side snapshot of a (possibly device-resident) pytree: one
    batched ``device_get``. This is the only part of a save that must run
    on the training thread — the returned (path, ndarray) list is
    immutable w.r.t. further training steps and safe to write from a
    background thread."""
    paths = _leaf_paths(tree)
    host = jax.device_get([leaf for _, leaf in paths])
    return [(p, np.asarray(a)) for (p, _), a in zip(paths, host)]


def write_snapshot(directory: str, step: int,
                   snapshot: List[Tuple[str, np.ndarray]],
                   keep: int = 3) -> str:
    """Write an already-host-resident snapshot: tmp dir, per-leaf files +
    sha256 manifest, meta.json, then one atomic rename publishes the
    step. Safe to call off-thread; touches no jax state."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + f".tmp{jax.process_index()}")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {}
    for i, (path, arr) in enumerate(snapshot):
        fn = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr, allow_pickle=False)
        with open(os.path.join(tmp, fn), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest[path] = {"file": fn, "sha256": digest,
                          "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish

    # retention
    steps = sorted(_complete_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"), ignore_errors=True)
    return final


def save_checkpoint(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    """Blocking save: snapshot on the caller, write, publish."""
    return write_snapshot(directory, step, snapshot_tree(tree), keep)


def _complete_steps(directory: str) -> list:
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and ".tmp" not in d:
            if os.path.exists(os.path.join(directory, d, "meta.json")):
                out.append(int(d.split("_")[1]))
    return out


def _verify_step(directory: str, step: int) -> None:
    """Raise if the published step's manifest or any leaf file fails
    presence/hash verification."""
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    for key, ent in meta["leaves"].items():
        fp = os.path.join(d, ent["file"])
        with open(fp, "rb") as f:
            if hashlib.sha256(f.read()).hexdigest() != ent["sha256"]:
                raise IOError(f"checkpoint corruption at {key} ({fp})")


def latest_step(directory: str, *, verify: bool = False) -> Optional[int]:
    """Newest complete step, or None. ``verify=True`` additionally
    hash-verifies candidates newest-first and returns the first that
    passes, warning (UserWarning) for each corrupt step it skips."""
    if not os.path.isdir(directory):
        return None
    steps = sorted(_complete_steps(directory), reverse=True)
    if not verify:
        return steps[0] if steps else None
    for s in steps:
        try:
            _verify_step(directory, s)
            return s
        except (OSError, KeyError, json.JSONDecodeError) as e:
            warnings.warn(
                f"skipping corrupt checkpoint step {s} under {directory}: "
                f"{e}", UserWarning, stacklevel=2)
    return None


def _restore_step(directory: str, template: Any, step: int,
                  verify: bool) -> Tuple[Any, int]:
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in flat:
        key = "/".join(str(getattr(k, "key", k)) for k in path)
        ent = meta["leaves"][key]          # KeyError -> leaf missing
        fp = os.path.join(d, ent["file"])
        if verify:
            with open(fp, "rb") as f:
                if hashlib.sha256(f.read()).hexdigest() != ent["sha256"]:
                    raise IOError(f"checkpoint corruption at {key} ({fp})")
        arr = np.load(fp, allow_pickle=False)
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {np.shape(tmpl)} — use elastic.reshard()")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, meta["step"]


def restore_checkpoint(
    directory: str, template: Any, step: Optional[int] = None,
    verify: bool = True,
) -> Tuple[Any, int]:
    """Restore into the structure of ``template`` (shapes must match).

    With ``step=None`` (the resume path), candidates are tried
    newest-first: a step that fails hash or leaf-presence verification is
    skipped with a UserWarning naming it and the next-newest complete
    step is tried, so one corrupt checkpoint can never strand a job that
    has an older valid one. An explicit ``step`` is a hard requirement
    and still fails loudly. Shape mismatches always propagate — they mean
    an elastic re-shard is needed, not corruption."""
    if step is not None:
        return _restore_step(directory, template, step, verify)
    if not os.path.isdir(directory):
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    steps = sorted(_complete_steps(directory), reverse=True)
    if not steps:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    errors = []
    for s in steps:
        try:
            return _restore_step(directory, template, s, verify)
        except ValueError:
            raise  # shape mismatch: elastic problem, not corruption
        except (OSError, KeyError, json.JSONDecodeError) as e:
            errors.append((s, e))
            warnings.warn(
                f"checkpoint step {s} under {directory} failed "
                f"verification ({e}); falling back to the next-newest "
                "complete step", UserWarning, stacklevel=2)
    raise IOError(
        f"every checkpoint under {directory} failed verification: "
        + "; ".join(f"step {s}: {e}" for s, e in errors))


# ------------------------------------------------------------- async tiers
class AsyncCheckpointer:
    """Two-tier asynchronous checkpoint writer (see module docstring).

    ``stats`` counts *scheduled* saves per tier (deterministic under a
    deterministic step schedule — the goodput bench exact-gates them) and
    accumulates ``stall_s``, the training-thread time spent inside
    snapshot+enqueue. Worker-side write failures never raise into the
    training loop: they are collected in ``errors`` and the torn step is
    simply absent from restore's candidate set (the atomic-rename
    protocol guarantees a failed write publishes nothing)."""

    #: restore preference order on equal steps (local is the fast medium)
    TIERS = ("local", "durable")

    def __init__(self, durable_dir: str, local_dir: Optional[str] = None, *,
                 durable_every: int = 50, local_every: int = 10,
                 keep_durable: int = 3, keep_local: int = 2):
        self.dirs = {"durable": durable_dir}
        if local_dir is not None:
            self.dirs["local"] = local_dir
        self.every = {"durable": durable_every, "local": local_every}
        self.keep = {"durable": keep_durable, "local": keep_local}
        self.stats = {"local": 0, "durable": 0, "stall_s": 0.0}
        self.errors: List[Exception] = []
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(
            target=self._run, name="ckpt-writer", daemon=True)
        self._worker.start()
        self._closed = False

    # --------------------------------------------------------- worker side
    def _run(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                directory, step, snap, keep = item
                write_snapshot(directory, step, snap, keep)
            except Exception as e:  # noqa: BLE001 — surfaced via .errors
                self.errors.append(e)
            finally:
                self._q.task_done()

    # ------------------------------------------------------- training side
    def save(self, step: int, tree: Any, tiers=("durable",)) -> list:
        """Snapshot once on the calling thread, enqueue one write per
        tier. Returns the tiers scheduled."""
        if self._closed:
            raise RuntimeError("AsyncCheckpointer is closed")
        tiers = [t for t in tiers if t in self.dirs]
        if not tiers:
            return []
        t0 = time.perf_counter()
        snap = snapshot_tree(tree)
        for tier in tiers:
            self._q.put((self.dirs[tier], step, snap, self.keep[tier]))
            self.stats[tier] += 1
        self.stats["stall_s"] += time.perf_counter() - t0
        return tiers

    def maybe_save(self, step: int, tree: Any) -> list:
        """Tier-cadence save: local every ``local_every`` steps, durable
        every ``durable_every`` (a step due in both tiers snapshots
        once)."""
        due = [t for t in ("local", "durable")
               if t in self.dirs and step % self.every[t] == 0]
        return self.save(step, tree, due) if due else []

    def drain(self) -> None:
        """Block until every enqueued write has been attempted. Write
        failures are warned about, not raised — a torn write is a lost
        checkpoint, which restore's fallback already handles."""
        self._q.join()
        if self.errors:
            warnings.warn(
                f"{len(self.errors)} checkpoint write(s) failed "
                f"(first: {self.errors[0]!r}); the affected steps were "
                "never published and restore will fall back",
                UserWarning, stacklevel=2)

    def close(self) -> None:
        if not self._closed:
            self.drain()
            self._closed = True
            self._q.put(None)
            self._worker.join()

    # ------------------------------------------------------------- restore
    def invalidate_local(self) -> None:
        """Drop the local tier's contents (drills: node loss takes the
        node-local SSD tier with it; only the durable tier survives)."""
        d = self.dirs.get("local")
        if d and os.path.isdir(d):
            shutil.rmtree(d)
            os.makedirs(d, exist_ok=True)

    def freshest(self, *, include_local: bool = True) -> list:
        """(tier, step) candidates, freshest step first (local wins
        ties), for observability and restore."""
        out = []
        for tier in self.TIERS:
            if tier == "local" and not include_local:
                continue
            d = self.dirs.get(tier)
            if d is None:
                continue
            s = latest_step(d)
            if s is not None:
                out.append((tier, s))
        return sorted(out, key=lambda ts: (-ts[1], self.TIERS.index(ts[0])))

    def restore(self, template: Any, *,
                include_local: bool = True) -> Tuple[Any, int, str]:
        """Restore the freshest valid checkpoint across tiers: candidates
        ordered freshest-first, each directory's own corrupt-step
        fallback applies within a tier, and a tier whose every step fails
        verification falls through to the next. Returns
        ``(state, step, tier)``."""
        self.drain()   # a write for step N scheduled before restore counts
        errors = []
        for tier, _ in self.freshest(include_local=include_local):
            try:
                state, step = restore_checkpoint(self.dirs[tier], template)
                return state, step, tier
            except (OSError, KeyError, json.JSONDecodeError) as e:
                errors.append((tier, e))
                warnings.warn(
                    f"checkpoint tier '{tier}' unusable ({e}); falling "
                    "back to the next tier", UserWarning, stacklevel=2)
        raise FileNotFoundError(
            "no restorable checkpoint in any tier"
            + (f" ({errors})" if errors else ""))
