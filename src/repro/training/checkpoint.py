"""Fault-tolerant checkpointing: atomic, content-verified, resumable.

Layout (one directory per step):

    <dir>/step_000123.tmp/...      (written first)
    <dir>/step_000123/             (atomic rename on completion)
        meta.json                  step, config hash, leaf manifest+sha256
        arr_000.npy ...            one file per pytree leaf

Restore picks the newest *complete* step (meta.json present and every leaf
hash verifies), so a crash mid-write can never be loaded. ``keep`` bounds
disk. Multi-host: each host writes only the shards it owns
(``process_index`` prefix) — on this single-process container that
degenerates to one writer, but the path layout is the multi-host one.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _leaf_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [
        ("/".join(str(getattr(k, "key", k)) for k in path), leaf)
        for path, leaf in flat
    ]


def save_checkpoint(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + f".tmp{jax.process_index()}")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {}
    for i, (path, leaf) in enumerate(_leaf_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr, allow_pickle=False)
        with open(os.path.join(tmp, fn), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest[path] = {"file": fn, "sha256": digest,
                          "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish

    # retention
    steps = sorted(_complete_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"), ignore_errors=True)
    return final


def _complete_steps(directory: str) -> list:
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not ".tmp" in d:
            if os.path.exists(os.path.join(directory, d, "meta.json")):
                out.append(int(d.split("_")[1]))
    return out


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = _complete_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str, template: Any, step: Optional[int] = None,
    verify: bool = True,
) -> Tuple[Any, int]:
    """Restore into the structure of ``template`` (shapes must match)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in flat:
        key = "/".join(str(getattr(k, "key", k)) for k in path)
        ent = meta["leaves"][key]
        fp = os.path.join(d, ent["file"])
        if verify:
            with open(fp, "rb") as f:
                if hashlib.sha256(f.read()).hexdigest() != ent["sha256"]:
                    raise IOError(f"checkpoint corruption at {key} ({fp})")
        arr = np.load(fp, allow_pickle=False)
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {np.shape(tmpl)} — use elastic.reshard()")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, meta["step"]
