"""Fault tolerance for long multi-pod runs: heartbeats, straggler detection,
and elastic re-meshing.

Real clusters surface failures as (a) a host stops heartbeating, or (b) a
host heartbeats but its step time degrades (straggler). The coordinator-side
logic here is pure and unit-testable; the transport (files on shared
storage) is what JAX multi-host deployments typically have available without
extra infrastructure.

Elastic policy: on node loss, shrink the data-parallel axis to the largest
feasible size, re-shard the latest checkpoint onto the surviving mesh, and
resume from the checkpointed step (data pipeline is (seed, step)-pure, so
no input state is lost). ``plan_remesh`` computes the new mesh;
``reshard_tree`` moves a host-sharded checkpoint onto it.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "Heartbeat", "HeartbeatBoard", "detect_failures", "detect_stragglers",
    "plan_remesh", "reshard_tree",
]


@dataclasses.dataclass
class Heartbeat:
    host: int
    step: int
    t_wall: float
    step_time_s: float


class HeartbeatBoard:
    """File-backed heartbeat board (one JSON blob per host)."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def beat(self, hb: Heartbeat) -> None:
        path = os.path.join(self.dir, f"host_{hb.host:05d}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(dataclasses.asdict(hb), f)
        os.replace(tmp, path)

    def read_all(self) -> List[Heartbeat]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("host_"):
                try:
                    with open(os.path.join(self.dir, fn)) as f:
                        out.append(Heartbeat(**json.load(f)))
                except (json.JSONDecodeError, TypeError):
                    continue  # torn write — treat as missing this round
        return out


def detect_failures(
    beats: List[Heartbeat], now: float, timeout_s: float = 60.0,
    expected_hosts: Optional[int] = None,
) -> List[int]:
    """Hosts that have not heartbeat within ``timeout_s``."""
    seen = {b.host: b for b in beats}
    dead = [h for h, b in seen.items() if now - b.t_wall > timeout_s]
    if expected_hosts is not None:
        dead += [h for h in range(expected_hosts) if h not in seen]
    return sorted(set(dead))


def detect_stragglers(
    beats: List[Heartbeat], factor: float = 2.0
) -> List[int]:
    """Hosts whose step time exceeds ``factor`` × the fleet median.

    Mitigation at the step level is up to the caller (typical: demote the
    host, or rebalance its data shard); detection is the hard part to get
    deterministic.
    """
    if len(beats) < 3:
        return []
    times = np.array([b.step_time_s for b in beats])
    med = float(np.median(times))
    return sorted(b.host for b in beats if b.step_time_s > factor * med)


def plan_remesh(
    n_healthy_chips: int, model_parallel: int, pod_size: int = 256
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest feasible mesh after failures.

    Keeps ``model_parallel`` fixed (param layout unchanged -> cheap
    re-shard) and shrinks data parallelism to the largest multiple that
    fits; drops to single-pod axes when fewer than 2 pods survive.
    """
    if n_healthy_chips < model_parallel:
        raise RuntimeError("not enough chips for the model-parallel degree")
    data = n_healthy_chips // model_parallel
    pods = max(1, n_healthy_chips // pod_size)
    if pods >= 2 and data % pods == 0:
        return (pods, data // pods, model_parallel), ("pod", "data", "model")
    return (data, model_parallel), ("data", "model")


def reshard_tree(tree, mesh, spec_tree):
    """Place a (host-local numpy) tree onto ``mesh`` with ``spec_tree``."""
    from jax.sharding import NamedSharding

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, spec_tree)
