"""Fault tolerance for long multi-pod runs: heartbeats, straggler detection,
and elastic re-meshing.

Real clusters surface failures as (a) a host stops heartbeating, or (b) a
host heartbeats but its step time degrades (straggler). The coordinator-side
logic here is pure and unit-testable; the transport (files on shared
storage) is what JAX multi-host deployments typically have available without
extra infrastructure.

Elastic policy: on node loss, shrink the data-parallel axis to the largest
feasible size, re-shard the latest checkpoint onto the surviving mesh, and
resume from the checkpointed step (data pipeline is (seed, step)-pure, so
no input state is lost). ``plan_remesh`` computes the new mesh;
``reshard_tree`` moves a host-sharded checkpoint onto it.

``FaultPlan`` is the *injection* side: a deterministic schedule of faults
(process kill, simulated device loss, injected straggler) that the drill
supervisor (``repro.training.supervisor``) executes against a live
training loop, so the detection/recovery machinery above is exercised by
a reproducible scenario instead of waiting for real hardware to die.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "Heartbeat", "HeartbeatBoard", "detect_failures", "detect_stragglers",
    "plan_remesh", "reshard_tree",
    "FAULT_KINDS", "FaultEvent", "FaultPlan", "make_fault_plan",
]


@dataclasses.dataclass
class Heartbeat:
    host: int
    step: int
    t_wall: float
    step_time_s: float


class HeartbeatBoard:
    """File-backed heartbeat board (one JSON blob per host)."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def beat(self, hb: Heartbeat) -> None:
        path = os.path.join(self.dir, f"host_{hb.host:05d}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(dataclasses.asdict(hb), f)
        os.replace(tmp, path)

    def read_all(self) -> List[Heartbeat]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("host_"):
                try:
                    with open(os.path.join(self.dir, fn)) as f:
                        out.append(Heartbeat(**json.load(f)))
                except (json.JSONDecodeError, TypeError):
                    continue  # torn write — treat as missing this round
        return out

    def clear(self, host: int) -> None:
        """Drop a host's beat file — decommissioning after node loss, so
        a permanently dead host does not re-trigger ``detect_failures``
        every monitoring round."""
        path = os.path.join(self.dir, f"host_{host:05d}.json")
        if os.path.exists(path):
            os.remove(path)


def detect_failures(
    beats: List[Heartbeat], now: float, timeout_s: float = 60.0,
    expected_hosts: Optional[int] = None,
) -> List[int]:
    """Hosts that have not heartbeat within ``timeout_s``."""
    seen = {b.host: b for b in beats}
    dead = [h for h, b in seen.items() if now - b.t_wall > timeout_s]
    if expected_hosts is not None:
        dead += [h for h in range(expected_hosts) if h not in seen]
    return sorted(set(dead))


def detect_stragglers(
    beats: List[Heartbeat], factor: float = 2.0
) -> List[int]:
    """Hosts whose step time exceeds ``factor`` × the fleet median.

    Mitigation at the step level is up to the caller (typical: demote the
    host, or rebalance its data shard); detection is the hard part to get
    deterministic.
    """
    if len(beats) < 3:
        return []
    times = np.array([b.step_time_s for b in beats])
    med = float(np.median(times))
    return sorted(b.host for b in beats if b.step_time_s > factor * med)


def plan_remesh(
    n_healthy_chips: int, model_parallel: int, pod_size: int = 256
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest feasible mesh after failures.

    Keeps ``model_parallel`` fixed (param layout unchanged -> cheap
    re-shard) and shrinks data parallelism to the largest multiple that
    fits; drops to single-pod axes when fewer than 2 pods survive.
    """
    if n_healthy_chips < model_parallel:
        raise RuntimeError("not enough chips for the model-parallel degree")
    data = n_healthy_chips // model_parallel
    pods = max(1, n_healthy_chips // pod_size)
    if pods >= 2 and data % pods == 0:
        return (pods, data // pods, model_parallel), ("pod", "data", "model")
    return (data, model_parallel), ("data", "model")


def reshard_tree(tree, mesh, spec_tree):
    """Place a (host-local numpy) tree onto ``mesh`` with ``spec_tree``."""
    from jax.sharding import NamedSharding

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, spec_tree)


# ------------------------------------------------------------ fault plans
#: The drill fault taxonomy. "kill": the training process dies at a step
#: boundary and restarts on the same fleet (node-local checkpoint tier
#: survives). "device_loss": a worker host's chips drop out permanently —
#: its node-local tier is lost with it, and the run resumes *elastically*
#: at a smaller data-parallel width from the durable tier. "straggler": a
#: host keeps stepping but its step time degrades by ``severity``× — no
#: restart, detection-only (the mitigation decision is logged).
FAULT_KINDS = ("kill", "device_loss", "straggler")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``step`` is the training step it fires at
    (kill/device_loss fire *before* the step runs — that step and every
    un-checkpointed predecessor must be recomputed; a straggler slows the
    step itself). ``severity``: hosts lost for device_loss, slowdown
    factor for a straggler."""
    step: int
    kind: str
    severity: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.step < 1:
            raise ValueError("faults fire at step >= 1 (step 0 has no "
                             "checkpoint to recover to but the init)")
        if self.severity < 1:
            raise ValueError("severity must be >= 1")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, step-ordered fault schedule. The drill supervisor
    injects each event exactly once; two restart-class faults may not
    share a step (there is nothing left to kill twice)."""
    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        steps = [e.step for e in self.events]
        if steps != sorted(steps):
            raise ValueError("FaultPlan events must be ordered by step")
        if len(set(steps)) != len(steps):
            raise ValueError("at most one fault per step")


def make_fault_plan(seed: int, steps: int, *, n_faults: int = 3,
                    kinds: Tuple[str, ...] = FAULT_KINDS,
                    min_gap: int = 2) -> FaultPlan:
    """Seeded random drill schedule: ``n_faults`` events at distinct
    steps in [1, steps), at least ``min_gap`` apart (recovery must get a
    chance to make forward progress between faults), cycling through
    ``kinds`` in a seeded shuffle. Deterministic across platforms
    (``np.random.RandomState``)."""
    candidates = list(range(1, steps))
    rng = np.random.RandomState(seed)
    chosen: List[int] = []
    rng.shuffle(candidates)
    for s in candidates:
        if all(abs(s - c) >= min_gap for c in chosen):
            chosen.append(s)
        if len(chosen) == n_faults:
            break
    if len(chosen) < n_faults:
        raise ValueError(
            f"cannot place {n_faults} faults with gap {min_gap} in "
            f"{steps} steps")
    kind_seq = [kinds[i % len(kinds)] for i in range(n_faults)]
    rng.shuffle(kind_seq)
    # a straggler below the detection factor is not a drill worth running:
    # 4x is the canonical injected slowdown (detectable at the default
    # factor=2 against a fleet median of nominal step times)
    return FaultPlan(tuple(
        FaultEvent(step=s, kind=k, severity=4 if k == "straggler" else 1)
        for s, k in zip(sorted(chosen), kind_seq)))
