"""Serving launcher: loads (or initializes) params and serves batched
requests through the slot engine (bucketed chunked prefill + on-device
sampling by default; ``--prefill-mode token`` runs the legacy
one-dispatch-per-token baseline for comparison).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --tokens 32
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig, energy_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--cim", default="off")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=256)
    ap.add_argument("--prefill-mode", default="bucketed",
                    choices=["bucketed", "token"])
    args = ap.parse_args()

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()
    if args.cim != "off":
        arch = arch.replace(cim=arch.cim.with_mode(args.cim))
    params = init_params(jax.random.PRNGKey(0), arch)
    eng = Engine(arch, params, ServeConfig(batch_slots=args.slots,
                                           max_ctx=args.ctx,
                                           prefill_mode=args.prefill_mode))
    t0 = time.perf_counter()
    eng.add_request(list(range(1, 9)))
    eng.add_request(list(range(20, 24)))
    out = eng.step()
    print(f"TTFT (2 prompts, incl. compile): "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms "
          f"({eng.stats['prefill_dispatches']} prefill dispatches, "
          f"mode={args.prefill_mode})")
    print(f"step 0: {out}")
    for i in range(1, args.tokens):
        out = eng.step()
        if i % 8 == 0:
            print(f"step {i}: {out}")
    if arch.cim.enabled:
        # ledger-derived, per phase: the serving deployment metric next to
        # the serving stats (decode aliases at top level)
        rep = energy_report(arch)
        print(f"energy (decode): {rep['pj_per_token']:.1f} pJ/token at "
              f"{rep['fj_per_op']:.1f} fJ/Op "
              f"(conventional {rep['conventional_fj_per_op']:.1f} fJ/Op)")
        for phase, ph in rep["phases"].items():
            print(f"  {phase:8s} {ph['pj_per_token']:12.1f} pJ/token "
                  f"({ph['analog_ops_per_token']:.3g} analog Ops/token)")
        print(f"engine pj/token: "
              f"{eng.energy_per_token()['pj_per_token']:.1f}")


if __name__ == "__main__":
    main()
