"""Serving launcher: loads (or initializes) params and serves batched
requests through the slot engine (bucketed chunked prefill + on-device
sampling by default; ``--prefill-mode token`` runs the legacy
one-dispatch-per-token baseline for comparison).

``--traffic N`` switches to open-loop serving: N seeded Poisson arrivals
at ``--rate`` requests/s are pushed through the continuous-batching
``Scheduler`` on the wall clock (real sleeps between arrivals), printing
per-request streams as they finish and the TTFT/TPOT percentile + goodput
summary at the end — the interactive twin of
``benchmarks/traffic_bench.py``'s virtual-clock sweep.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --tokens 32
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --traffic 12 --rate 20
    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --reduced \
        --traffic 12 --spec-k 4 --spec-draft digital
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Engine, ServeConfig, energy_report
from repro.serving.scheduler import (
    Scheduler, SchedulerConfig, run_open_loop, synth_traffic)


def _serve_traffic(arch, params, args) -> None:
    eng = Engine(arch, params, ServeConfig(batch_slots=args.slots,
                                           max_ctx=args.ctx))
    spec = None
    if args.spec_k > 1:
        from repro.serving.speculative import SpecConfig
        spec = SpecConfig(k=args.spec_k, draft=args.spec_draft)
    sched = Scheduler(
        eng, SchedulerConfig(prefill_token_budget=args.prefill_budget),
        spec=spec)
    traffic = synth_traffic(args.traffic, args.rate, seed=args.seed,
                            vocab_size=arch.vocab_size,
                            prompt_len=(8, 48),
                            out_len=(4, args.tokens))
    t0 = time.perf_counter()
    run_open_loop(sched, traffic)
    wall = time.perf_counter() - t0
    for r in sorted(sched.finished, key=lambda r: r.rid):
        print(f"req {r.rid:3d}: prompt={len(r.prompt):3d} tok, "
              f"generated={r.n_generated:3d} ({r.finish_reason}), "
              f"ttft={1e3 * (r.ttft_wall or 0.0):7.1f} ms, "
              f"tpot={1e3 * (r.tpot_wall or 0.0):6.2f} ms"
              + (f", preempted x{r.preemptions}" if r.preemptions else ""))
    m = sched.metrics(slo_ttft=None)
    print(f"\ntraffic: {m['completed']} completed / {m['rejected']} rejected "
          f"in {wall:.2f} s at {args.rate:g} req/s "
          f"({m['decode_steps']} decode steps, "
          f"{m['prefill_dispatches']} prefill dispatches, "
          f"queue depth max {m['queue_depth_max']})")
    print(f"TTFT p50/p99: {m['ttft_p50_ms']:.1f}/{m['ttft_p99_ms']:.1f} ms | "
          f"TPOT p50/p99: {m['tpot_p50_ms']:.2f}/{m['tpot_p99_ms']:.2f} ms | "
          f"goodput {m['goodput_tok_s']:.1f} tok/s")
    if m["spec_steps"]:
        print(f"spec: {m['accepted_tokens_per_step']:.2f} accepted "
              f"tok/step over {m['spec_steps']} steps "
              f"({m['draft_dispatches']} draft / "
              f"{m['verify_dispatches']} verify / "
              f"{m['repair_dispatches']} repair dispatches)")
    if arch.cim.enabled:
        print(f"energy: {m['pj_per_token']:.1f} pJ/token "
              f"({m['energy_pj'] / 1e6:.2f} uJ total decode)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--cim", default="off")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=256)
    ap.add_argument("--prefill-mode", default="bucketed",
                    choices=["bucketed", "token"])
    ap.add_argument("--traffic", type=int, default=0, metavar="N",
                    help="serve N open-loop Poisson arrivals through the "
                         "continuous-batching scheduler instead of the "
                         "fixed two-prompt demo")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="--traffic arrival rate, requests per second")
    ap.add_argument("--prefill-budget", type=int, default=16,
                    help="--traffic prefill tokens interleaved per step")
    ap.add_argument("--spec-k", type=int, default=1,
                    help="speculative lookahead for --traffic serving "
                         "(1 = sequential decode; k >= 2 drafts k-1 "
                         "tokens per iteration and verifies them in one "
                         "chunked dispatch)")
    ap.add_argument("--spec-draft", default="digital",
                    choices=["digital", "self"],
                    help="draft policy for --spec-k: 'digital' drafts "
                         "with the CIM path off, 'self' with the target "
                         "config itself")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()
    if args.cim != "off":
        arch = arch.replace(cim=arch.cim.with_mode(args.cim))
    params = init_params(jax.random.PRNGKey(0), arch)
    if args.traffic:
        _serve_traffic(arch, params, args)
        return
    eng = Engine(arch, params, ServeConfig(batch_slots=args.slots,
                                           max_ctx=args.ctx,
                                           prefill_mode=args.prefill_mode))
    t0 = time.perf_counter()
    eng.add_request(list(range(1, 9)))
    eng.add_request(list(range(20, 24)))
    out = eng.step()
    print(f"TTFT (2 prompts, incl. compile): "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms "
          f"({eng.stats['prefill_dispatches']} prefill dispatches, "
          f"mode={args.prefill_mode})")

    def fmt(res):   # the typed per-request stream, not the raw dict
        return ", ".join(f"slot {o.slot}: {o.tokens}"
                         + (f" <{o.finish_reason}>" if o.finished else "")
                         for o in res.outputs)

    print(f"step 0: {fmt(out)}")
    for i in range(1, args.tokens):
        out = eng.step()
        if i % 8 == 0:
            print(f"step {i}: {fmt(out)}")
    if arch.cim.enabled:
        # ledger-derived, per phase: the serving deployment metric next to
        # the serving stats (decode aliases at top level)
        rep = energy_report(arch)
        print(f"energy (decode): {rep['pj_per_token']:.1f} pJ/token at "
              f"{rep['fj_per_op']:.1f} fJ/Op "
              f"(conventional {rep['conventional_fj_per_op']:.1f} fJ/Op)")
        for phase, ph in rep["phases"].items():
            print(f"  {phase:8s} {ph['pj_per_token']:12.1f} pJ/token "
                  f"({ph['analog_ops_per_token']:.3g} analog Ops/token)")
        print(f"engine pj/token: "
              f"{eng.energy_per_token()['pj_per_token']:.1f}")


if __name__ == "__main__":
    main()
