"""Production training launcher: builds the mesh, shards params/opt state,
and runs the resilient training loop. On this CPU container it runs the
local mesh; on a real cluster the same code runs under jax.distributed.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --steps 20 --batch 8 --seq 128

``--drill`` runs the same configuration under the supervised fault-drill
harness instead: a seeded FaultPlan (kill / device loss / straggler) is
injected into the loop, failures are detected and recovered (freshest
checkpoint tier, elastic resume), and the run reports its GoodPut
partition and fault counters (see ``repro.training.supervisor``).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --reduced --steps 12 --drill --drill-faults 3
"""
import argparse
import json
import tempfile

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.parallel.sharding import use_mesh
from repro.training.fault import make_fault_plan
from repro.training.optimizer import OptimizerConfig
from repro.training.supervisor import DrillConfig, Supervisor, price_drill
from repro.training.trainer import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--cim", default="off")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--heartbeat-dir", default=None)
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices)")
    ap.add_argument("--drill", action="store_true",
                    help="run under the supervised fault-drill harness "
                         "(seeded kill/device-loss/straggler injection)")
    ap.add_argument("--drill-faults", type=int, default=3)
    ap.add_argument("--drill-seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()
    if args.cim != "off":
        arch = arch.replace(cim=arch.cim.with_mode(args.cim))

    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq,
                      vocab_size=arch.vocab_size,
                      embedding_dim=arch.d_model
                      if arch.input_mode == "embeddings" else 0)
    tcfg = TrainConfig(
        steps=args.steps, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir, log_every=5,
        opt=OptimizerConfig(lr=args.lr, warmup_steps=5,
                            total_steps=args.steps))
    if args.drill:
        plan = make_fault_plan(args.drill_seed, args.steps,
                               n_faults=args.drill_faults)
        with tempfile.TemporaryDirectory() as wd, use_mesh(mesh):
            drill_cfg = DrillConfig(workdir=args.ckpt_dir or wd,
                                    steps=args.steps)
            report = Supervisor(arch, tcfg, drill_cfg, SyntheticLM(dcfg),
                                plan, seed=args.drill_seed).run_drill()
        report["energy"] = price_drill(
            arch, report, tokens_per_step=args.batch * args.seq)
        report.pop("losses")
        print(json.dumps(report, indent=1))
        return

    with use_mesh(mesh):
        metrics = train(arch, tcfg, SyntheticLM(dcfg),
                        heartbeat_dir=args.heartbeat_dir)
    print("final:", metrics)


if __name__ == "__main__":
    main()
