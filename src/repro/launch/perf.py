import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Perf-iteration driver: run one dry-run cell with a named experiment
variant (config overrides) and print the roofline-term deltas vs baseline.

    python -m repro.launch.perf --arch arctic-480b --shape train_4k \
        --variant microbatch8 --set microbatches=8
"""
import argparse
import json

from repro.launch.dryrun import run_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--roofline-mode", action="store_true", default=True)
    ap.add_argument("--no-roofline-mode", dest="roofline_mode",
                    action="store_false")
    ap.add_argument("--cim", default="off")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--cache-dtype", default="bfloat16")
    ap.add_argument("--algorithm", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--layout", default="fsdp", choices=["fsdp", "zero1"])
    ap.add_argument("--model-parallel", type=int, default=16)
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override key=value (int/float/bool/str)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON to diff against")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "false"):
            v = v == "true"
        overrides[k] = v

    r = run_cell(args.arch, args.shape, False, cim=args.cim,
                 roofline_mode=args.roofline_mode, overrides=overrides,
                 tag_suffix=f"_{args.variant}",
                 microbatches=args.microbatches,
                 grad_compression=args.grad_compression,
                 cache_dtype=args.cache_dtype,
                 algorithm=args.algorithm, layout=args.layout,
                 model_parallel=args.model_parallel,
                 out_dir="experiments/perf")
    if args.baseline and r["status"] == "ok":
        base = json.load(open(args.baseline))
        br, nr = base["roofline"], r["roofline"]
        print(f"{'term':14s} {'baseline':>12s} {'variant':>12s} {'delta':>8s}")
        for k in ("t_compute_s", "t_memory_s", "t_collective_s"):
            d = (nr[k] - br[k]) / max(br[k], 1e-12) * 100
            print(f"{k:14s} {br[k]:12.4f} {nr[k]:12.4f} {d:+7.1f}%")
        bm = base["bytes_per_device"]["peak_est"] / 2**30
        nm = r["bytes_per_device"]["peak_est"] / 2**30
        print(f"{'mem GiB/dev':14s} {bm:12.2f} {nm:12.2f} "
              f"{(nm-bm)/bm*100:+7.1f}%")


if __name__ == "__main__":
    main()
