"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / (links × link_bw)

``cost_analysis()`` on a GSPMD-partitioned module reports *per-device*
numbers (verified empirically, see tests/test_dryrun.py), so the chip count
cancels out of the assignment's formulas. Collective bytes are not in
cost_analysis: we parse the post-optimization HLO and sum the output-shape
bytes of every collective op, weighting all-reduce at 2× (reduce-scatter +
all-gather ring cost) and intra-op all-gather/reduce-scatter at 1×.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict

from repro.launch.mesh import HW

__all__ = ["collective_bytes", "RooflineReport", "roofline_from_compiled"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?"
    r"(\(?[a-z0-9\[\],\s{}:/]*\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")

# ring all-reduce ≈ reduce-scatter + all-gather
_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Weighted bytes moved per device, by collective kind."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # async pairs appear as -start/-done; count each op once (the -start
        # carries the shapes; -done repeats them)
        line = hlo_text[m.start(): hlo_text.find("\n", m.start())]
        if f"{kind}-done" in line:
            continue
        out[kind] = out.get(kind, 0.0) + _WEIGHT[kind] * _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float           # 6·N_active·D (global)
    peak_util_bound: float       # model_flops share of compute-term time

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def hlo_per_model_flops(self) -> float:
        global_hlo = self.flops_per_device * self.chips
        return global_hlo / self.model_flops if self.model_flops else math.nan

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline the cell can reach: useful-FLOP
        time over the max of all three terms."""
        t_useful = (self.model_flops / self.chips) / HW.PEAK_FLOPS_BF16
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / bound if bound else math.nan

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo/model": self.hlo_per_model_flops,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_estimate(cfg, case) -> float:
    """6·N_active·D for training, 2·N_active·D per generated/scored token
    otherwise (N = active params, D = tokens processed)."""
    n_active = _active_params(cfg)
    if case.kind == "train":
        tokens = case.global_batch * case.seq_len
        return 6.0 * n_active * tokens
    if case.kind == "prefill":
        tokens = case.global_batch * case.seq_len
        return 2.0 * n_active * tokens
    tokens = case.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


def _active_params(cfg) -> float:
    """Parameters touched per token (MoE: top-k experts only; embeddings
    excluded, LM head included)."""
    total = cfg.param_count()
    if cfg.input_mode == "tokens":
        total -= cfg.vocab_size * cfg.d_model  # embedding lookup isn't a matmul
    if cfg.is_moe:
        e_ff = cfg.expert_d_ff
        nmat = 3 if cfg.gated_mlp else 2
        per_layer = nmat * cfg.d_model * e_ff
        n_moe_layers = sum(1 for k in cfg.blocks() if k != "ssm")
        total -= (cfg.n_experts - cfg.top_k) * per_layer * n_moe_layers
    return float(total)


def roofline_from_compiled(
    arch_name, shape_name, mesh_name, chips, compiled, cfg, case
) -> RooflineReport:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    coll_total = sum(coll.values())
    mf = model_flops_estimate(cfg, case)
    t_c = flops / HW.PEAK_FLOPS_BF16
    t_m = byts / HW.HBM_BW
    # each v5e chip drives ~4 ICI links; DCN (pod axis) is far slower but
    # carries only the small "pod"-axis reductions — fold into one term.
    t_x = coll_total / (4 * HW.ICI_BW)
    return RooflineReport(
        arch=arch_name, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=coll_total, coll_breakdown=coll,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        model_flops=mf,
        peak_util_bound=(mf / chips / HW.PEAK_FLOPS_BF16),
    )
