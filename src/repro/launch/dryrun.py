import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes, print memory/cost analysis, extract roofline
terms, and persist JSON per cell under experiments/dryrun/.

Usage:
    python -m repro.launch.dryrun --arch qwen2-1.5b --shape decode_32k
    python -m repro.launch.dryrun --all [--multi-pod] [--cim grmac]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_from_compiled
from repro.launch.specs import SHAPES, cell_is_runnable, make_cell
from repro.parallel.sharding import use_mesh

ARCHS = [
    "arctic-480b", "grok-1-314b", "qwen2-1.5b", "gemma3-1b", "granite-8b",
    "stablelm-3b", "mamba2-1.3b", "recurrentgemma-9b", "musicgen-medium",
    "chameleon-34b",
]


def run_cell(arch: str, shape: str, multi_pod: bool, cim: str = "off",
             out_dir: str = "experiments/dryrun", verbose: bool = True,
             roofline_mode: bool = False, overrides: dict | None = None,
             tag_suffix: str = "", microbatches: int = 1,
             grad_compression: bool = False, cache_dtype: str = "bfloat16",
             algorithm: str = "adamw", layout: str = "fsdp",
             model_parallel: int = 16):
    """One dry-run cell.

    roofline_mode=False: production lowering (scan over layers, chunked
      attention) — proves compile + per-device memory.
    roofline_mode=True: unrolled layers + unchunked attention so
      cost_analysis / collective parsing count every op exactly once
      (scan bodies are otherwise counted once, not x trip-count).
    """
    mesh = make_production_mesh(multi_pod=multi_pod,
                                model_parallel=model_parallel)
    dp = 256 // model_parallel
    mesh_name = (f"2x{dp}x{model_parallel}" if multi_pod
                 else f"{dp}x{model_parallel}")
    chips = mesh.size
    cfg = get_config(arch)
    if cim != "off":
        cfg = cfg.replace(cim=cfg.cim.with_mode(cim))
    if roofline_mode:
        cfg = cfg.replace(scan_layers=False, attn_chunk=None)
    if overrides:
        cfg = cfg.replace(**overrides)
    ok, reason = cell_is_runnable(cfg, shape)
    result = {"arch": arch, "shape": shape, "mesh": mesh_name, "cim": cim}
    os.makedirs(out_dir, exist_ok=True)
    tag = (f"{arch}_{shape}_{mesh_name}" + (f"_{cim}" if cim != "off" else "")
           + ("_roofline" if roofline_mode else "") + tag_suffix)
    path = os.path.join(out_dir, tag + ".json")
    if not ok:
        result.update(status="skipped", reason=reason)
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        if verbose:
            print(f"[dryrun] {tag}: SKIPPED ({reason})")
        return result

    t0 = time.time()
    try:
        with use_mesh(mesh):
            import jax.numpy as jnp
            cell = make_cell(cfg, shape, mesh,
                             cache_dtype=jnp.dtype(cache_dtype),
                             microbatches=microbatches,
                             grad_compression=grad_compression,
                             algorithm=algorithm, layout=layout)
            jitted = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums,
            )
            lowered = jitted.lower(*cell.in_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        rep = roofline_from_compiled(
            arch, shape, mesh_name, chips, compiled, cfg, SHAPES[shape])
        result.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            bytes_per_device={
                "arguments": ma.argument_size_in_bytes,
                "outputs": ma.output_size_in_bytes,
                "temps": ma.temp_size_in_bytes,
                "aliased": ma.alias_size_in_bytes,
                "peak_est": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            cost={
                "flops_per_device": rep.flops_per_device,
                "bytes_per_device": rep.bytes_per_device,
                "collective_bytes_per_device": rep.coll_bytes_per_device,
                "collective_breakdown": rep.coll_breakdown,
            },
            roofline=rep.row(),
        )
        if verbose:
            gb = result["bytes_per_device"]["peak_est"] / 2**30
            print(f"[dryrun] {tag}: OK compile={t_compile:.0f}s "
                  f"mem/dev={gb:.2f}GiB dominant={rep.dominant} "
                  f"roofline_frac={rep.roofline_fraction:.3f}")
    except Exception as e:  # report, don't crash the sweep
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[dryrun] {tag}: ERROR {type(e).__name__}: {e}")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--cim", default="off", choices=["off", "fakequant", "grmac"])
    ap.add_argument("--roofline-mode", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_skip = n_err = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                r = run_cell(a, s, mp, cim=args.cim, out_dir=args.out,
                             roofline_mode=args.roofline_mode)
                st = r["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
