"""Production mesh construction.

Single pod: 16 × 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 × 16 × 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries pure data parallelism across the slow inter-pod links
(DCN); "data" is FSDP within a pod; "model" is tensor/expert parallel on
the fastest ICI dimension.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


class HW:
    """TPU v5e-class hardware constants for the roofline model."""

    PEAK_FLOPS_BF16 = 197e12      # per chip
    HBM_BW = 819e9                # B/s per chip
    ICI_BW = 50e9                 # B/s per link
    HBM_BYTES = 16 * 2**30        # 16 GiB per chip
    VMEM_BYTES = 128 * 2**20


def make_production_mesh(*, multi_pod: bool = False, model_parallel: int = 16):
    """Production mesh over 256 (single pod) or 512 (2 pods) chips.

    ``model_parallel`` re-maps the *logical* axis split over the same
    hardware: model_parallel=1 is pure data parallelism (TP=1) — the right
    choice for models whose activation all-reduce cost exceeds their
    FSDP weight-gather cost (e.g. 1.5B dense at 1M tokens/step, §Perf).
    """
    chips_per_pod = 256
    dp = chips_per_pod // model_parallel
    shape = (2, dp, model_parallel) if multi_pod else (dp, model_parallel)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """A 1×1 mesh over whatever single device is present (tests/examples)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
