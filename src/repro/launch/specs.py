"""Abstract input specs (ShapeDtypeStruct) + shardings for every
(architecture × input shape) dry-run cell. Nothing here allocates.

Shapes (assignment):
    train_4k      seq 4096,   global_batch 256   -> train_step
    prefill_32k   seq 32768,  global_batch 32    -> prefill (forward)
    decode_32k    seq 32768,  global_batch 128   -> serve_step (1 new token)
    long_500k     seq 524288, global_batch 1     -> serve_step; only for
                  sub-quadratic archs (gemma3 / mamba2 / recurrentgemma)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import init_cache, init_params
from repro.parallel.sharding import batch_axes, param_specs
from repro.training.optimizer import OptimizerConfig, init_opt_state

__all__ = ["SHAPES", "ShapeCase", "cell_is_runnable", "make_cell"]


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}


def cell_is_runnable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch at 512k context "
                       "(see DESIGN.md §5)")
    return True, ""


# ------------------------------------------------------------------ specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, case: ShapeCase):
    b, s = case.global_batch, case.seq_len
    if cfg.input_mode == "tokens":
        inputs = _sds((b, s), jnp.int32)
    else:
        inputs = _sds((b, s, cfg.d_model), jnp.float32)
    return {
        "inputs": inputs,
        "labels": _sds((b, s), jnp.int32),
        "mask": _sds((b, s), jnp.float32),
    }


def batch_shardings(cfg: ArchConfig, case: ShapeCase, mesh: Mesh):
    ba = batch_axes(mesh)
    bspec = ba if case.global_batch % _size(mesh, ba) == 0 else None
    spec = {
        "inputs": (P(bspec, None) if cfg.input_mode == "tokens"
                   else P(bspec, None, None)),
        "labels": P(bspec, None),
        "mask": P(bspec, None),
    }
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda s: isinstance(s, P))


def _size(mesh, ax):
    if ax is None:
        return 1
    import numpy as np

    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return mesh.shape[ax]


def cache_spec_tree(cfg: ArchConfig, case: ShapeCase, mesh: Mesh, shapes):
    """PartitionSpec tree for the decode cache, by leaf path + rank.

    Policy: batch over the data axes when divisible; the *context/seq* dim
    of full-attention KV over "model" (context-parallel decode); SSM heads
    and RG-LRU width over "model"; local-window caches batch-only.
    For batch=1 (long_500k) the seq dim takes both axis groups.
    """
    ba = batch_axes(mesh)
    model_ok = lambda d: d % mesh.shape["model"] == 0

    def spec_for(path: str, shp) -> P:
        rank = len(shp.shape)
        stacked = rank >= 1 and "superblocks" in path
        lead = (None,) if stacked else ()
        dims = shp.shape[1:] if stacked else shp.shape
        b = dims[0]
        bspec = ba if b % _size(mesh, ba) == 0 else None
        if path.endswith("/k") or path.endswith("/v"):
            _, s, kv, dh = dims
            is_global = s == case.seq_len
            if is_global:
                if bspec is None:
                    both = (tuple(ba) if ba else ()) + ("model",)
                    sspec = both if s % _size(mesh, both) == 0 else (
                        "model" if model_ok(s) else None)
                else:
                    sspec = "model" if model_ok(s) else None
                return P(*lead, bspec, sspec, None, None)
            return P(*lead, bspec, None, None, None)
        if "/conv" in path:
            c = dims[-1]
            return P(*lead, bspec, None, "model" if model_ok(c) else None)
        if path.endswith("/h") and len(dims) == 4:   # ssm state (B,NH,N,P)
            nh = dims[1]
            return P(*lead, bspec, "model" if model_ok(nh) else None, None, None)
        if path.endswith("/h") and len(dims) == 2:   # rglru state (B,W)
            w = dims[1]
            return P(*lead, bspec, "model" if model_ok(w) else None)
        return P()

    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]

    def name(pth):
        return "/".join(str(getattr(k, "key", k)) for k in pth)

    lookup = {name(pth): spec_for(name(pth), v) for pth, v in flat}
    return jax.tree_util.tree_map_with_path(
        lambda pth, v: lookup[name(pth)], shapes)


# ------------------------------------------------------------------ cells
@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch × shape × mesh) dry-run cell."""

    fn: object            # the function to jit
    in_specs: tuple       # abstract ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: object
    donate_argnums: tuple = ()


def make_cell(cfg: ArchConfig, shape: str, mesh: Mesh,
              cache_dtype=jnp.bfloat16, microbatches: int = 1,
              grad_compression: bool = False, algorithm: str = "adamw",
              layout: str = "fsdp") -> Cell:
    """Build the jit-able callable + abstract inputs for one cell."""
    from repro.models import decode_step, forward, train_loss
    from repro.training.trainer import TrainConfig, make_train_step

    case = SHAPES[shape]
    ba = batch_axes(mesh)

    if case.kind == "train":
        params_shape = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        p_specs = param_specs(params_shape, mesh,
                              use_fsdp=(layout == "fsdp"))
        ocfg = OptimizerConfig(grad_compression=grad_compression,
                               algorithm=algorithm)
        opt_shape = jax.eval_shape(lambda: init_opt_state(params_shape, ocfg))
        o_specs = param_specs(opt_shape, mesh)  # opt always sharded (ZeRO-1)
        tcfg = TrainConfig(opt=ocfg, microbatches=microbatches)
        fn = make_train_step(cfg, tcfg)
        bspecs = batch_specs(cfg, case)
        bshard = batch_shardings(cfg, case, mesh)
        ns = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda s: isinstance(s, P))
        return Cell(
            fn=fn,
            in_specs=(params_shape, opt_shape, bspecs),
            in_shardings=(ns(p_specs), ns(o_specs), bshard),
            out_shardings=(ns(p_specs), ns(o_specs), None),
            donate_argnums=(0, 1),
        )

    if case.kind == "prefill":
        params_shape = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        p_specs = param_specs(params_shape, mesh)
        bspecs = batch_specs(cfg, case)
        bshard = batch_shardings(cfg, case, mesh)

        def fn(params, inputs):
            logits, _, _ = forward(params, inputs, cfg)
            return logits

        ns = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda s: isinstance(s, P))
        return Cell(
            fn=fn,
            in_specs=(params_shape, bspecs["inputs"]),
            in_shardings=(ns(p_specs), bshard["inputs"]),
            out_shardings=None,
        )

    # decode
    b = case.global_batch
    params_shape = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    p_specs = param_specs(params_shape, mesh)
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, b, case.seq_len, cache_dtype))
    c_specs = cache_spec_tree(cfg, case, mesh, cache_shape)
    if cfg.input_mode == "tokens":
        tok = _sds((b, 1), jnp.int32)
    else:
        tok = _sds((b, 1, cfg.d_model), jnp.float32)
    idx = _sds((), jnp.int32)

    def fn(params, token, cache, cache_index):
        return decode_step(params, token, cfg, cache, cache_index)

    ns = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda s: isinstance(s, P))
    bspec = ba if b % _size(mesh, ba) == 0 else None
    tok_spec = P(bspec, None) if cfg.input_mode == "tokens" else P(bspec, None, None)
    return Cell(
        fn=fn,
        in_specs=(params_shape, tok, cache_shape, idx),
        in_shardings=(ns(p_specs), NamedSharding(mesh, tok_spec),
                      ns(c_specs), NamedSharding(mesh, P())),
        out_shardings=(None, ns(c_specs)),
        donate_argnums=(2,),
    )
