"""Aggregate dry-run / roofline / energy JSON cells into the
EXPERIMENTS.md tables (``--energy`` renders the ledger-derived per-phase
pJ/token record written by ``benchmarks/e2e_energy.py``, plus — when the
``e2e_pareto`` record exists — each arch's per-site Pareto frontier, the
chosen ``site_overrides`` deployment, and the per-phase deployment-level
energy/accuracy fronts from ``--pareto``)."""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(out_dir: str, roofline: bool):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        d = json.load(open(f))
        is_roof = f.endswith("_roofline.json")
        if is_roof != roofline:
            continue
        rows.append(d)
    return rows


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(rows):
    print("| arch | shape | mesh | status | compile_s | mem/dev GiB | "
          "flops/dev | coll GiB/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for d in rows:
        if d["status"] != "ok":
            print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                  f"{d['status']}: {d.get('reason', d.get('error',''))[:60]} "
                  f"| | | | |")
            continue
        print(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok "
            f"| {d['compile_s']} | {fmt_bytes(d['bytes_per_device']['peak_est'])} "
            f"| {d['cost']['flops_per_device']:.3g} "
            f"| {d['cost']['collective_bytes_per_device']/2**30:.3f} |")


def roofline_table(rows):
    """Three assignment terms + a fusion-adjusted memory *lower bound*
    (args+outputs traffic only — perfect fusion), bracketing real TPU HBM
    time between t_mem_lb and t_mem(HLO upper bound)."""
    hbm_bw = 819e9
    print("| arch | shape | t_comp ms | t_mem ms (UB) | t_mem_lb ms "
          "| t_coll ms | dominant (bracket) | HLO/model | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in rows:
        if d["status"] != "ok":
            continue
        r = d["roofline"]
        b = d["bytes_per_device"]
        t_lb = (b["arguments"] + b["outputs"]) / hbm_bw
        terms = {"compute": r["t_compute_s"], "memory_lb": t_lb,
                 "collective": r["t_collective_s"]}
        dom_lb = max(terms, key=terms.get)
        dom = r["dominant"] if r["dominant"] == dom_lb.replace("_lb", "") \
            else f"{r['dominant']}→{dom_lb}"
        print(
            f"| {d['arch']} | {d['shape']} "
            f"| {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
            f"| {t_lb*1e3:.2f} "
            f"| {r['t_collective_s']*1e3:.2f} | {dom} "
            f"| {r['hlo/model']:.2f} | {r['roofline_fraction']:.3f} |")


def energy_table(path: str):
    """Per-arch × per-phase pJ/token from the trace-derived CostLedger
    record (benchmarks/e2e_energy.py): the deployment bottom line."""
    with open(path) as f:
        recs = json.load(f)
    print("| arch | fJ/Op (conv) | decode pJ/tok | prefill pJ/tok | "
          "train pJ/tok | decode GOps/tok |")
    print("|---|---|---|---|---|---|")
    for arch, r in sorted(recs.items()):
        ph = r["phases"]
        print(
            f"| {arch} | {r['fj_per_op']:.1f} "
            f"({r['conventional_fj_per_op']:.1f}) "
            f"| {ph['decode']['pj_per_token']:.0f} "
            f"| {ph['prefill']['pj_per_token']:.0f} "
            f"| {ph['train']['pj_per_token']:.0f} "
            f"| {ph['decode']['ops_per_token']/1e9:.3f} |")


def _fmt_design(d: dict) -> str:
    return f"{d['fmt_x']}/n{d['n_r']}/{d['granularity']}"


def pareto_table(path: str):
    """Per-arch per-site Pareto frontier (decode phase) + the chosen
    ``site_overrides`` deployment and the per-phase deployment-level
    energy/accuracy fronts, from the ``--pareto`` record."""
    try:
        with open(path) as f:
            recs = json.load(f)
    except OSError:
        return
    for arch, rec in sorted(recs.items()):
        dec = rec["phases"]["decode"]
        print(f"\n### {arch} — per-site frontier @ decode "
              f"(budget {rec['budget_sqnr_db']:.1f} dB)")
        print("| site | GOps/tok | chosen | fJ/Op | SQNR dB | "
              "front (fJ/Op @ dB) |")
        print("|---|---|---|---|---|---|")
        for site, s in sorted(dec["sites"].items()):
            if s.get("mode") == "off" or "front" not in s:
                print(f"| {site} | {s['ops_per_token']/1e9:.3f} | off "
                      "| | | |")
                continue
            chosen = s["chosen"]
            front = " → ".join(
                f"{c['fj_per_op']:.1f}@{c['sqnr_db']:.1f}"
                for c in s["front"].values())
            fj = s["chosen_fj_per_op"]
            sq = next((c["sqnr_db"] for k, c in s["front"].items()
                       if k == chosen), None)
            print(f"| {site} | {s['ops_per_token']/1e9:.3f} | {chosen} "
                  f"| {fj:.1f} | {sq:.1f} | {front} |"
                  if fj is not None else
                  f"| {site} | {s['ops_per_token']/1e9:.3f} | {chosen} "
                  f"| | | {front} |")
        ov = dec["site_overrides"]
        print("site_overrides: "
              + json.dumps(ov, sort_keys=True, default=str))
        for phase, ph in rec["phases"].items():
            pts = " → ".join(
                f"{k}:{p['pj_per_token']:.0f}pJ"
                for k, p in ph["front"].items())
            print(f"{phase}: chosen {ph['pj_per_token']:.0f} pJ/tok "
                  f"(base {ph['base_pj_per_token']:.0f}) | front: {pts}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--energy", action="store_true",
                    help="render experiments/bench/e2e_energy.json (+ the "
                         "e2e_pareto frontier record when present)")
    ap.add_argument("--energy-record",
                    default="experiments/bench/e2e_energy.json")
    ap.add_argument("--pareto-record",
                    default="experiments/bench/e2e_pareto.json")
    args = ap.parse_args()
    if args.energy:
        energy_table(args.energy_record)
        pareto_table(args.pareto_record)
        return
    rows = load(args.dir, args.roofline)
    if args.roofline:
        roofline_table(rows)
    else:
        dryrun_table(rows)


if __name__ == "__main__":
    main()
