"""Launcher glue for the static-analysis audit.

Same entry point as ``python -m repro.analysis`` (kept here so every
runnable surface of the repo lives under ``repro.launch``):

    PYTHONPATH=src python -m repro.launch.audit --all-configs \
        --out experiments/audit/audit_report.json
"""
from repro.analysis.cli import main

if __name__ == "__main__":
    raise SystemExit(main())
