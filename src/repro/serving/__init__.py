"""Serving stack: the slot ``Engine`` and the continuous-batching
``Scheduler`` above it.

Two layers, one seam
--------------------
* ``engine``    — mechanism. Fixed-size decode batch ("slots"), bucketed
  chunked prefill (one compiled dispatch per power-of-two chunk), fused
  on-device sampling (exactly one device→host transfer per decode step),
  per-slot EOS freeing, and ledger-derived pJ/token
  (``StepResult.pj_per_token``). The incremental prefill API
  (``begin_request`` / ``advance_prefill`` / ``finish_prefill`` /
  ``release_slot`` / ``free_slots``) is the scheduler seam:
  ``add_request`` is the blocking composition of the same methods.
* ``scheduler`` — policy. FIFO queue with WAITING → PREFILLING →
  RUNNING → FINISHED states (plus PREEMPTED under overload), admission
  control against free slots and ``max_ctx``, chunked prefill
  interleaved into decode iterations under a per-step token budget, and
  per-request TTFT/TPOT/pJ-per-token accounting with SLO-conditioned
  goodput. See ``scheduler``'s module docstring for the state machine,
  budget semantics, preemption policy, and goodput definitions.

Benchmarks: ``benchmarks/serve_bench.py`` (fixed-batch TTFT/TPOT),
``benchmarks/traffic_bench.py`` (open-loop Poisson traffic: goodput vs
arrival rate, saturation knee, continuous vs static batching).
Invariants: ``repro.analysis.invariants`` proves the compile budget and
one-transfer-per-step rules hold under both hand-placed and
scheduler-driven serving.
"""
from repro.serving.engine import Engine, ServeConfig, StepResult, energy_report
from repro.serving.scheduler import (
    Request,
    Scheduler,
    SchedulerConfig,
    StaticBatchScheduler,
    StepClock,
    run_open_loop,
    synth_traffic,
)

__all__ = [
    "Engine", "ServeConfig", "StepResult", "energy_report",
    "Request", "Scheduler", "SchedulerConfig", "StaticBatchScheduler",
    "StepClock", "run_open_loop", "synth_traffic",
]
