"""Serving stack: the slot ``Engine``, the ``PrefixCache`` beside it,
and the continuous-batching ``Scheduler`` above both.

Three layers, two seams
-----------------------
* ``engine``    — mechanism. Fixed-size decode batch ("slots"), bucketed
  chunked prefill (one compiled dispatch per power-of-two chunk), fused
  on-device sampling (exactly one device→host transfer per decode step),
  per-slot EOS freeing, and ledger-derived pJ/token
  (``StepResult.pj_per_token``). The incremental prefill API
  (``begin_request`` / ``advance_prefill`` / ``finish_prefill`` /
  ``release_slot`` / ``free_slots``) is the scheduler seam:
  ``add_request`` is the blocking composition of the same methods.
* ``prefix_cache`` — reuse. A trie over token-id chunks caching per-slot
  prefill snapshots so shared system prompts prefill once.
* ``scheduler`` — policy. FIFO (or shortest-prompt-first with an
  anti-starvation age bound) queue with WAITING → PREFILLING →
  RUNNING → FINISHED states (plus PREEMPTED under overload), admission
  control against free slots and ``max_ctx``, chunked prefill
  interleaved into decode iterations under a per-step token budget, and
  per-request TTFT/TPOT/pJ-per-token accounting with SLO-conditioned
  goodput. See ``scheduler``'s module docstring for the state machine,
  budget semantics, preemption policy, and goodput definitions.

Prefix-cache design note
------------------------
``ServeConfig.prefix_cache_bytes`` turns reuse on; the essentials
(full detail in ``prefix_cache``'s module docstring):

* **Key alignment.** Trie edges are ``prefill_bucket_min``-token
  chunks, so every cached boundary is a length the existing
  power-of-two bucket executables already serve — adopting a prefix and
  prefilling the suffix introduces **zero new compiles**, and the
  compile-budget / one-transfer invariants are re-proven under a
  hit-heavy trace (``repro.analysis.invariants.run_prefix_invariants``).
* **Snapshot layout per arch family.** Snapshots mirror the engine
  cache pytree with the slot lane extracted: ``attn`` layers store K/V
  rows ``[:P]`` (sliceable to any shorter shared prefix for
  pure-attention archs — RadixAttention-style subsumption); ``local``
  ring buffers are copied whole (validity re-derives from the restored
  length); ``rglru``/``ssm`` store the recurrent state + conv tail — a
  few KB per prefix regardless of its length, the fixed-state economy
  the GPU paged-KV stacks don't have. Capture and restore are
  device-side (no host crossing).
* **Eviction.** One LRU over snapshot entries under the byte budget;
  hits refresh recency, evicted entries prune their trie path, counters
  (hits/misses/inserts/evictions/bytes) are exact-gated in CI.
* **Exactness contract.** Snapshots are captured live at chunk-aligned
  boundaries during prefill; bucketed==token chunking equivalence makes
  a restored prefix bit-identical to a cold lane, so hit streams equal
  cold-prefill streams exactly (tested across attn/rglru/ssm/moe).
  Lookup always leaves ≥1 suffix token so ``finish_prefill`` has real
  last-token logits.

Follow-up (ROADMAP item 2): block/paged KV layout so attention restores
stop copying dense lanes, then disaggregated prefill/decode engines
with explicit KV/state handoff.

Benchmarks: ``benchmarks/serve_bench.py`` (fixed-batch TTFT/TPOT),
``benchmarks/traffic_bench.py`` (open-loop Poisson + closed-loop
fixed-concurrency traffic: goodput vs arrival rate, saturation knee,
continuous vs static batching, shared-prefix cache-on vs cache-off).
Invariants: ``repro.analysis.invariants`` proves the compile budget and
one-transfer-per-step rules hold under hand-placed, scheduler-driven,
and prefix-hit-heavy serving.
"""
from repro.serving.engine import Engine, ServeConfig, StepResult, energy_report
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import (
    Request,
    Scheduler,
    SchedulerConfig,
    StaticBatchScheduler,
    StepClock,
    run_closed_loop,
    run_open_loop,
    synth_shared_prefix_traffic,
    synth_traffic,
)

__all__ = [
    "Engine", "ServeConfig", "StepResult", "energy_report", "PrefixCache",
    "Request", "Scheduler", "SchedulerConfig", "StaticBatchScheduler",
    "StepClock", "run_open_loop", "run_closed_loop", "synth_traffic",
    "synth_shared_prefix_traffic",
]
