"""Serving stack: the slot ``Engine``, the ``PrefixCache`` beside it,
and the continuous-batching ``Scheduler`` above both.

Three layers, two seams
-----------------------
* ``engine``    — mechanism. Fixed-size decode batch ("slots"), bucketed
  chunked prefill (one compiled dispatch per power-of-two chunk), fused
  on-device sampling (exactly one device→host transfer per decode step),
  per-slot EOS freeing, and ledger-derived pJ/token
  (``StepResult.pj_per_token``). The incremental prefill API
  (``begin_request`` / ``advance_prefill`` / ``finish_prefill`` /
  ``release_slot`` / ``free_slots``) is the scheduler seam:
  ``add_request`` is the blocking composition of the same methods.
* ``prefix_cache`` — reuse. A trie over token-id chunks caching per-slot
  prefill snapshots so shared system prompts prefill once.
* ``scheduler`` — policy. FIFO (or shortest-prompt-first with an
  anti-starvation age bound) queue with WAITING → PREFILLING →
  RUNNING → FINISHED states (plus PREEMPTED under overload), admission
  control against free slots and ``max_ctx``, chunked prefill
  interleaved into decode iterations under a per-step token budget, and
  per-request TTFT/TPOT/pJ-per-token accounting with SLO-conditioned
  goodput. See ``scheduler``'s module docstring for the state machine,
  budget semantics, preemption policy, and goodput definitions.

Prefix-cache design note
------------------------
``ServeConfig.prefix_cache_bytes`` turns reuse on; the essentials
(full detail in ``prefix_cache``'s module docstring):

* **Key alignment.** Trie edges are ``prefill_bucket_min``-token
  chunks, so every cached boundary is a length the existing
  power-of-two bucket executables already serve — adopting a prefix and
  prefilling the suffix introduces **zero new compiles**, and the
  compile-budget / one-transfer invariants are re-proven under a
  hit-heavy trace (``repro.analysis.invariants.run_prefix_invariants``).
* **Snapshot layout per arch family.** Snapshots mirror the engine
  cache pytree with the slot lane extracted: ``attn`` layers store K/V
  rows ``[:P]`` (sliceable to any shorter shared prefix for
  pure-attention archs — RadixAttention-style subsumption); ``local``
  ring buffers are copied whole (validity re-derives from the restored
  length); ``rglru``/``ssm`` store the recurrent state + conv tail — a
  few KB per prefix regardless of its length, the fixed-state economy
  the GPU paged-KV stacks don't have. Capture and restore are
  device-side (no host crossing).
* **Eviction.** One LRU over snapshot entries under the byte budget;
  hits refresh recency, evicted entries prune their trie path, counters
  (hits/misses/inserts/evictions/bytes) are exact-gated in CI.
* **Exactness contract.** Snapshots are captured live at chunk-aligned
  boundaries during prefill; bucketed==token chunking equivalence makes
  a restored prefix bit-identical to a cold lane, so hit streams equal
  cold-prefill streams exactly (tested across attn/rglru/ssm/moe).
  Lookup always leaves ≥1 suffix token so ``finish_prefill`` has real
  last-token logits.

Follow-up (ROADMAP item 2): block/paged KV layout so attention restores
stop copying dense lanes, then disaggregated prefill/decode engines
with explicit KV/state handoff.

Request API
-----------
``SamplingParams`` is the single typed entry for per-request knobs
(temperature, seed, eos_id, max_tokens, spec_k) — passed to
``Engine.add_request`` / ``begin_request`` and ``Scheduler.submit`` as
``params=``; the legacy ``eos_id=`` / ``max_new_tokens=`` kwargs
convert bit-identically for one release under a ``DeprecationWarning``.
Results stream back as typed ``RequestOutput`` records on
``StepResult.outputs`` — per-request tokens, finish flag, finish reason
(``"eos"`` / ``"length"`` / ``"ctx"``), and lazy pJ/token — the one
shape engine, scheduler, bench, and ``launch/serve.py`` all consume.

Speculative-decode design note
------------------------------
``speculative.SpecDecoder`` wraps an engine and emits up to ``k``
tokens per iteration: draft ``k - 1`` with a cheap CIM config of the
*same* model on the *same* cache (no second model, no draft prefill),
verify all of them in ONE chunked dispatch through the exact grmac
path — the existing bucketed prefill executables are the verifier, so
greedy verification adds **zero new compiles** — and keep the longest
accepted prefix. Greedy acceptance is bit-identical to sequential
decode across attn/rglru/ssm/moe for any drafter; sampled acceptance
applies the standard rejection rule on device (unbiased, seeded).
Recurrent archs roll back via O(1) ``spec_snapshot`` refs + a
device-side per-lane restore, then one fetch-free repair dispatch
re-feeds accepted prefixes; global-attention KV needs no rollback at
all. ``speculative.price_speculation`` prices draft + verify against
sequential decode on the CostLedger (pJ/accepted-token), asking whether
speculation is an energy win and not just a latency win. Full detail
in ``speculative``'s module docstring;
``repro.analysis.invariants.run_spec_invariants`` machine-checks the
compile/transfer claims.

Benchmarks: ``benchmarks/serve_bench.py`` (fixed-batch TTFT/TPOT),
``benchmarks/traffic_bench.py`` (open-loop Poisson + closed-loop
fixed-concurrency traffic: goodput vs arrival rate, saturation knee,
continuous vs static batching, shared-prefix cache-on vs cache-off),
``benchmarks/spec_bench.py`` (sequential vs speculative per cache
family: accepted-tokens/step, TTLT speedup, pJ/accepted-token verdict).
Invariants: ``repro.analysis.invariants`` proves the compile budget and
one-transfer-per-step rules hold under hand-placed, scheduler-driven,
prefix-hit-heavy, and speculative serving.
"""
from repro.serving.engine import Engine, ServeConfig, StepResult, energy_report
from repro.serving.params import RequestOutput, SamplingParams
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import (
    Request,
    Scheduler,
    SchedulerConfig,
    StaticBatchScheduler,
    StepClock,
    run_closed_loop,
    run_open_loop,
    synth_shared_prefix_traffic,
    synth_traffic,
)
from repro.serving.speculative import SpecConfig, SpecDecoder

__all__ = [
    "Engine", "ServeConfig", "StepResult", "energy_report", "PrefixCache",
    "RequestOutput", "SamplingParams", "SpecConfig", "SpecDecoder",
    "Request", "Scheduler", "SchedulerConfig", "StaticBatchScheduler",
    "StepClock", "run_open_loop", "run_closed_loop", "synth_traffic",
    "synth_shared_prefix_traffic",
]
