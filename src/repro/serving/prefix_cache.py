"""Hashed-prefix KV / recurrent-state cache for the serving engine.

At production scale most traffic shares long system-prompt prefixes, yet
every request re-runs its full prefill — repeating exactly the analog
MAC + ADC work the paper identifies as energy-dominant. This module lets
shared prefixes prefill once: a trie keyed on token-id chunks stores
per-slot cache snapshots, and ``Engine.begin_request`` adopts the
longest cached prefix into the claimed lane so only the suffix is
dispatched (SGLang RadixAttention / vLLM automatic-prefix-caching
style, specialized to this engine's dense per-slot cache layout).

Key alignment (the zero-new-compiles contract)
----------------------------------------------
Trie edges are ``chunk_tokens``-token tuples and snapshots live only at
multiples of ``chunk_tokens`` — the engine passes its
``prefill_bucket_min`` (the smallest power-of-two prefill bucket), so
every cached length is a chunk-boundary the *existing* bucket
executables already serve. After adopting a prefix of P tokens the
engine prefills the suffix through the same power-of-two bucket
dispatches as a cold prompt starting at cache index P; no new bucket
length (hence no new compile) can be introduced by a hit, and the
compile-budget invariant (≤1 trace per (arch, bucket) executable) is
re-proven under a hit-heavy trace by
``repro.analysis.invariants.run_prefix_invariants``.

Snapshot layout per arch family
-------------------------------
A snapshot mirrors the engine cache pytree (``superblocks`` carry the
batch on axis 1, ``tail`` on axis 0) with the slot lane extracted; the
layer-name suffix (``b0_attn`` → ``attn``) selects the policy:

* ``attn`` — global attention writes K/V linearly by position, so the
  snapshot stores only the first P context rows per head (the "KV slice
  up to the cached length"). Restore writes them back at ``[:P]``.
* ``local`` — sliding-window attention keeps a ring buffer (writes at
  ``pos % window``); validity is derived from the restored length, so
  the snapshot stores the full (small) ring verbatim.
* ``rglru`` / ``ssm`` — the whole prefix collapses into one recurrent
  state (h + conv tail): a full copy of the per-slot state, a few KB
  regardless of prefix length. This is the angle GPU paged-KV stacks
  don't have — for the recurrent archs a cached prefix is nearly free,
  the same fixed-state economy AFPR-CIM exploits in hardware.

Snapshots are captured and restored **device-side** (jnp slicing /
``.at[].set``): nothing crosses to the host, so the engine's
one-D2H-transfer-per-decode-step invariant holds under hits.

Attention-only subsumption
--------------------------
When every cached layer is ``attn`` (pure-attention archs, including
MoE-over-attention), a stored snapshot of N tokens can serve any
shorter shared prefix of P < N tokens by slicing its KV rows to ``[:P]``
— lookup therefore matches the *divergence point*, not just exact
stored lengths. Recurrent states cannot be rewound, so mixed/recurrent
archs hit only at exactly-stored boundaries (their insert is cheap
enough to store every boundary instead). Boundary density follows the
prefill chunking: the scheduler's budgeted path naturally lands a
boundary per budget-sized chunk, while a blocking ``add_request`` only
stores the chunk ends it actually dispatches (one per
``prefill_bucket_max``) — so interleaved serving, the production path,
is also the cache-dense one.

Eviction policy
---------------
One LRU over snapshot-bearing trie nodes under ``byte_budget`` (sum of
snapshot leaf ``nbytes``). Lookup hits refresh recency (for sliced hits,
the donor entry's). Inserting past the budget evicts least-recently-used
entries until it fits; an entry larger than the whole budget is refused.
Evicted nodes prune their now-empty trie paths. Counters
(``hits/misses/inserts/evictions/hit_tokens/bytes``) are deterministic
functions of the request stream and are exact-gated by
``benchmarks/compare.py`` in CI.

Exactness contract
------------------
Snapshots are captured live at chunk-aligned boundaries *during*
prefill (recurrent state at an interior length is not recoverable after
the fact), and tests/test_serving_prefill.py already proves bucketed
chunked prefill is bit-identical to the token-by-token oracle for every
chunking. A restored prefix therefore reproduces the cold lane state
bit-for-bit, and the full generated stream after a hit is bit-identical
to a cold prefill of the same prompt (asserted across all four arch
families in tests/test_prefix_cache.py). Lookup always leaves ≥1 suffix
token unadopted so ``finish_prefill`` has real last-token logits to
select the first output from.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax

__all__ = ["PrefixCache", "snapshot_slot", "restore_slot"]

# cache pytree groups and the axis their per-layer leaves carry the
# batch (slot) dimension on — the engine's layout contract
_GROUPS = (("superblocks", 1), ("tail", 0))


def _kind(layer_name: str) -> str:
    """Layer-family suffix of an engine cache layer name (``b0_attn`` →
    ``attn``; ``t1_ssm`` → ``ssm``)."""
    return layer_name.split("_", 1)[1]


def _take(arr, batch_axis: int, slot: int):
    """Extract one slot lane (full copy — ring buffers, recurrent h/conv)."""
    idx = [slice(None)] * arr.ndim
    idx[batch_axis] = slot
    return arr[tuple(idx)]


def _take_ctx(arr, batch_axis: int, slot: int, length: int):
    """Extract one slot lane's first ``length`` context rows (linear
    positional K/V: the context axis follows the batch axis)."""
    idx = [slice(None)] * arr.ndim
    idx[batch_axis] = slot
    idx[batch_axis + 1] = slice(0, length)
    return arr[tuple(idx)]


def _put(arr, batch_axis: int, slot: int, val):
    idx = [slice(None)] * arr.ndim
    idx[batch_axis] = slot
    return arr.at[tuple(idx)].set(val)


def _put_ctx(arr, batch_axis: int, slot: int, length: int, val):
    idx = [slice(None)] * arr.ndim
    idx[batch_axis] = slot
    idx[batch_axis + 1] = slice(0, length)
    return arr.at[tuple(idx)].set(val)


def snapshot_slot(cache, slot: int, length: int) -> dict:
    """Device-side snapshot of one slot lane at prefix ``length``:
    ``attn`` layers keep only their first ``length`` K/V rows, every
    other family (ring buffers, recurrent states) is copied whole.
    Mirrors the cache pytree structure so restore is a structural zip."""
    out = {}
    for group, axis in _GROUPS:
        if group not in cache:
            continue
        g = {}
        for name, layer in cache[group].items():
            if _kind(name) == "attn":
                g[name] = {k: _take_ctx(a, axis, slot, length)
                           for k, a in layer.items()}
            else:
                g[name] = jax.tree.map(lambda a: _take(a, axis, slot), layer)
        out[group] = g
    return out


def restore_slot(cache, slot: int, length: int, snap: dict) -> dict:
    """Write a snapshot back into one slot lane of a (possibly larger)
    engine cache; the inverse of ``snapshot_slot``. Purely functional —
    returns the new cache pytree."""
    out = dict(cache)
    for group, axis in _GROUPS:
        if group not in cache:
            continue
        g = dict(cache[group])
        for name, layer in snap[group].items():
            if _kind(name) == "attn":
                g[name] = {k: _put_ctx(cache[group][name][k], axis, slot,
                                       length, v)
                           for k, v in layer.items()}
            else:
                g[name] = jax.tree.map(
                    lambda a, v: _put(a, axis, slot, v),
                    cache[group][name], layer)
        out[group] = g
    return out


def _snap_bytes(snap: dict) -> int:
    return sum(leaf.nbytes for leaf in jax.tree.leaves(snap))


def _slice_snapshot(snap: dict, length: int) -> dict:
    """Rewind an attention-only snapshot to a shorter prefix by slicing
    every K/V leaf's context axis to ``[:length]`` (context is axis 1
    under ``superblocks`` — after dropping the batch axis — and axis 0
    under ``tail``). Only valid when every layer kind is ``attn``."""
    out = {}
    for group, axis in _GROUPS:
        if group not in snap:
            continue
        ctx_axis = axis  # the batch axis was extracted: ctx shifted down 1
        g = {}
        for name, layer in snap[group].items():
            assert _kind(name) == "attn", "sliced lookup on non-attn layer"
            def cut(a):
                idx = [slice(None)] * a.ndim
                idx[ctx_axis] = slice(0, length)
                return a[tuple(idx)]
            g[name] = {k: cut(a) for k, a in layer.items()}
        out[group] = g
    return out


def _sliceable(snap: dict) -> bool:
    return all(_kind(name) == "attn"
               for group, _ in _GROUPS if group in snap
               for name in snap[group])


class _Node:
    __slots__ = ("children", "parent", "edge", "snap", "length", "nbytes")

    def __init__(self, parent=None, edge=None):
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.edge = edge          # chunk tuple keying this node in parent
        self.snap = None          # snapshot pytree, or None (path-only node)
        self.length = 0           # prefix tokens covered by self.snap
        self.nbytes = 0


class PrefixCache:
    """Chunk-aligned prefix trie with LRU-evicted per-slot snapshots.

    ``chunk_tokens`` must equal the engine's ``prefill_bucket_min`` so
    every stored boundary composes with the existing bucket executables
    (the engine asserts this when wiring the cache in).
    """

    def __init__(self, byte_budget: int, chunk_tokens: int = 8):
        if chunk_tokens < 1:
            raise ValueError("chunk_tokens must be >= 1")
        self.chunk = int(chunk_tokens)
        self.byte_budget = int(byte_budget)
        self._root = _Node()
        # LRU over snapshot-bearing nodes: dict insertion order, oldest
        # first (Python dicts are ordered; touch = delete + re-add)
        self._lru: Dict[_Node, None] = {}
        self._sliceable: Optional[bool] = None  # learned from first insert
        self.stats = {"hits": 0, "misses": 0, "inserts": 0,
                      "evictions": 0, "hit_tokens": 0, "bytes": 0}

    # ------------------------------------------------------------- lookup
    def lookup(self, prompt: List[int]) -> Optional[Tuple[int, dict]]:
        """Longest usable cached prefix of ``prompt``: returns
        ``(length, snapshot)`` or None. Capped at ``len(prompt) - 1`` so
        at least one suffix token remains to prefill (``finish_prefill``
        needs real last-token logits). Counts one hit or miss."""
        usable = (len(prompt) - 1) // self.chunk  # whole chunks adoptable
        node, depth = self._root, 0
        best: Optional[_Node] = None
        while depth < usable:
            nxt = node.children.get(
                tuple(prompt[depth * self.chunk:(depth + 1) * self.chunk]))
            if nxt is None:
                break
            node, depth = nxt, depth + 1
            if node.snap is not None:
                best = node
        if self._sliceable and depth > (best.length // self.chunk
                                        if best else 0):
            # attention-only: any stored descendant of the deepest matched
            # node shares its first depth*chunk tokens with the prompt —
            # slice the most recently used one down to the match point
            donor = self._mru_descendant(node)
            if donor is not None and donor.length > depth * self.chunk:
                self._touch(donor)
                self.stats["hits"] += 1
                self.stats["hit_tokens"] += depth * self.chunk
                return depth * self.chunk, _slice_snapshot(
                    donor.snap, depth * self.chunk)
        if best is None:
            self.stats["misses"] += 1
            return None
        self._touch(best)
        self.stats["hits"] += 1
        self.stats["hit_tokens"] += best.length
        return best.length, best.snap

    def _mru_descendant(self, node: _Node) -> Optional[_Node]:
        """Most recently used snapshot-bearing node in ``node``'s subtree
        (including itself)."""
        found = None
        for cand in reversed(self._lru):  # MRU first
            n = cand
            while n is not None:
                if n is node:
                    found = cand
                    break
                n = n.parent
            if found is not None:
                break
        return found

    # ------------------------------------------------------------- insert
    def insert(self, prefix: List[int], snap_fn) -> bool:
        """Store a snapshot for ``prefix`` (length must be a positive
        multiple of ``chunk``). ``snap_fn()`` builds the snapshot pytree
        lazily — it is not called when the boundary is already cached
        (identical prefix ⇒ identical state, by the determinism
        contract). Returns True when a new entry was stored."""
        n = len(prefix)
        if n <= 0 or n % self.chunk:
            raise ValueError(
                f"prefix length {n} not a positive multiple of chunk "
                f"{self.chunk}")
        node = self._root
        for d in range(n // self.chunk):
            key = tuple(prefix[d * self.chunk:(d + 1) * self.chunk])
            nxt = node.children.get(key)
            if nxt is None:
                nxt = node.children[key] = _Node(node, key)
            node = nxt
        if node.snap is not None:
            self._touch(node)
            return False
        snap = snap_fn()
        nbytes = _snap_bytes(snap)
        if nbytes > self.byte_budget:
            # can never fit; refuse rather than thrash, and prune any
            # path nodes this attempt created
            while (node.parent is not None and not node.children
                   and node.snap is None):
                parent = node.parent
                del parent.children[node.edge]
                node = parent
            return False
        if self._sliceable is None:
            self._sliceable = _sliceable(snap)
        node.snap, node.length, node.nbytes = snap, n, nbytes
        self._lru[node] = None
        self.stats["bytes"] += nbytes
        self.stats["inserts"] += 1
        while self.stats["bytes"] > self.byte_budget:
            self._evict(next(iter(self._lru)))
        return True

    # ----------------------------------------------------------- internals
    def _touch(self, node: _Node) -> None:
        del self._lru[node]
        self._lru[node] = None

    def _evict(self, node: _Node) -> None:
        self.stats["bytes"] -= node.nbytes
        self.stats["evictions"] += 1
        node.snap, node.length, node.nbytes = None, 0, 0
        del self._lru[node]
        # prune now-empty path suffix so the trie doesn't accrete tokens
        while (node.parent is not None and not node.children
               and node.snap is None):
            parent = node.parent
            del parent.children[node.edge]
            node = parent

    def __len__(self) -> int:
        return len(self._lru)

    @property
    def bytes(self) -> int:
        return self.stats["bytes"]

    def hit_rate(self) -> float:
        n = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / n if n else 0.0
