"""Continuous-batching scheduler over the slot ``Engine``.

The engine has the fast serving primitives — bucketed chunked prefill,
fused one-transfer decode, per-slot EOS freeing — but no brain above
them: callers hand-place requests into slots and ``add_request`` raises
when they are full. This module is that brain: a vLLM-style scheduler
with a FIFO request queue (or shortest-prompt-first admission with an
anti-starvation age bound — ``SchedulerConfig.admission``), admission
control, chunked prefill *interleaved* into decode iterations under a
per-step token budget, and per-request TTFT/TPOT/pJ-per-token
accounting. When the engine carries a prefix cache
(``repro.serving.prefix_cache``) admission adopts cached prefixes
transparently: the prefill budget is charged only for suffix tokens
actually dispatched, preemption recompute-resume becomes a (mostly)
cache hit, and ``metrics()`` reports hit rate,
``prefill_tokens_saved`` and ``recompute_tokens_saved`` beside the
cache counters. Traffic drivers: ``run_open_loop`` (Poisson offered
load) and ``run_closed_loop`` (fixed client concurrency).

Per-request knobs enter through ``SamplingParams``
(``submit(prompt, params=...)``; legacy ``max_new_tokens``/``eos_id``
kwargs convert under a DeprecationWarning), and every decode iteration
emits a typed ``RequestOutput`` stream that the scheduler consumes
instead of poking the slot->token dict — which is what lets
``Scheduler(..., spec=SpecConfig(...))`` swap the sequential engine
step for ``serving.speculative.SpecDecoder`` multi-token iterations
without the bookkeeping noticing (the virtual clock charges draft/
verify/repair dispatches one unit each, so speculation's fewer-
dispatches-per-token win is visible in goodput-per-step).

Queue states
------------
::

    WAITING ──admit──▶ PREFILLING ──finish_prefill──▶ RUNNING ──▶ FINISHED
       ▲                    │                            │
       └────── PREEMPTED ◀──┴────────────────────────────┘

* **WAITING**    — queued, no slot. FIFO order (arrival order as
  submitted; re-queued preempted requests go to the *back*).
* **PREFILLING** — slot claimed (``Engine.begin_request``); the prompt
  drains chunk-by-chunk through ``advance_prefill``. The lane is not in
  the decode batch yet, so mid-prefill requests cost decode lanes
  nothing.
* **RUNNING**    — prefill finished (``finish_prefill`` sampled the first
  output token — that instant is the request's TTFT); the lane decodes
  one token per engine step.
* **PREEMPTED**  — evicted under overload (see below); resumes by
  *recompute*: its prompt-so-far (original prompt + generated tokens)
  re-prefills when re-admitted, which reconstructs the evicted cache
  exactly, so a preempted greedy request's token stream is identical to
  an uninterrupted run.
* **FINISHED**   — terminal. ``finish_reason`` is one of ``"eos"``
  (engine-reported EOS), ``"length"`` (scheduler-side ``max_new_tokens``
  stop, or a resume that can no longer fit the context), ``"ctx"``
  (engine context exhaustion at ``max_ctx``), or ``"rejected"``
  (admission control: the prompt can never fit ``max_ctx``).

Prefill token budget
--------------------
``SchedulerConfig.prefill_token_budget`` caps how many *prompt* tokens
may be prefilled per scheduler step, spent FIFO across PREFILLING
requests. Each spend is one bucketed chunk dispatch of at most
``min(budget_left, remaining, prefill_bucket_max)`` tokens — a
budget-truncated chunk pads up to the next power-of-two bucket, so
interleaving reuses exactly the bucket executables the blocking path
compiles (no new compiles). A bounded budget keeps running lanes'
inter-token latency (TPOT) bounded: every scheduler step runs at most
``budget`` prompt tokens of prefill before the decode dispatch. Budget
``None`` prefills each admitted prompt to completion at admission — with
that setting and a never-overflowing arrival schedule the scheduler is
dispatch-for-dispatch identical to hand-placed
``add_request``/``step`` calls (tested in tests/test_scheduler.py).

Preemption policy
-----------------
Slots are fixed-size dense caches, so there is no mid-decode memory
overflow to react to; preemption here is queue-overload anti-starvation,
off by default. With ``preempt_age`` set, when the queue head has waited
longer than ``preempt_age`` (policy-clock units) and no slot is free,
the scheduler evicts the most recently admitted in-flight request (LIFO,
at most one per step, ``Engine.release_slot``) and re-queues it at the
back in recompute mode. The freed slot admits the starved head on the
same step.

Goodput
-------
``Scheduler.metrics(slo_ttft=...)`` defines goodput the way the serving
literature does: **completed tokens per unit time counting only requests
that met the latency SLO** (here: policy-clock TTFT ≤ ``slo_ttft``;
rejected requests never count). Tokens-per-policy-step
(``goodput_tok_per_step``) is deterministic under the virtual
``StepClock`` — the bench gates it exactly — while
``goodput_tok_s`` uses wall time. The open-loop traffic bench
(benchmarks/traffic_bench.py) sweeps Poisson arrival rates through this
and reports the saturation knee.

Clocks: every event is stamped twice — with the injectable policy
``clock`` (virtual ``StepClock`` in benches: deterministic scheduling
and SLO accounting) and with ``time.perf_counter()`` wall time (latency
metrics in ms, machine-dependent). Real deployments pass a wall clock as
the policy clock and the two coincide.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.serving.engine import Engine
from repro.serving.params import SamplingParams

__all__ = [
    "WAITING", "PREFILLING", "RUNNING", "PREEMPTED", "FINISHED",
    "Request", "SchedulerConfig", "Scheduler", "StaticBatchScheduler",
    "StepClock", "synth_traffic", "synth_shared_prefix_traffic",
    "run_open_loop", "run_closed_loop",
]

# request states (plain strings: they go straight into JSON reports)
WAITING = "waiting"
PREFILLING = "prefilling"
RUNNING = "running"
PREEMPTED = "preempted"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One queued generation request plus its measured lifecycle.

    ``t_*`` timestamps are policy-clock (virtual steps in the benches),
    ``wall_*`` are ``time.perf_counter()`` seconds; ``generated`` holds
    every emitted token including the prefill-sampled first one, across
    preemptions."""
    rid: int
    prompt: List[int]
    max_new_tokens: Optional[int] = None
    arrival: float = 0.0
    eos_id: Optional[int] = None
    # the request's full SamplingParams (the canonical knob record;
    # max_new_tokens/eos_id above mirror it for compatibility)
    params: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    state: str = WAITING
    slot: Optional[int] = None
    finish_reason: Optional[str] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0
    # resume prompt after preemption (original prompt + generated so far)
    resume_prompt: Optional[List[int]] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_finish: Optional[float] = None
    wall_arrival: Optional[float] = None
    wall_admit: Optional[float] = None
    wall_first: Optional[float] = None
    wall_finish: Optional[float] = None

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first is None:
            return None
        return self.t_first - self.arrival

    @property
    def ttft_wall(self) -> Optional[float]:
        if self.wall_first is None or self.wall_arrival is None:
            return None
        return self.wall_first - self.wall_arrival

    @property
    def tpot_wall(self) -> Optional[float]:
        """Wall seconds per output token after the first (None until
        finished or with a single token)."""
        if self.wall_finish is None or self.wall_first is None:
            return None
        if self.n_generated <= 1:
            return None
        return (self.wall_finish - self.wall_first) / (self.n_generated - 1)


@dataclasses.dataclass
class SchedulerConfig:
    # max prompt tokens prefilled per scheduler step (None = unbounded:
    # every admitted prompt prefills to completion at admission, i.e. the
    # blocking add_request behavior)
    prefill_token_budget: Optional[int] = 128
    # anti-starvation preemption (None = never preempt): when the queue
    # head has waited > preempt_age policy units and no slot is free,
    # evict the most recently admitted in-flight request (recompute)
    preempt_age: Optional[float] = None
    # admission ordering over the WAITING queue: "fifo" (arrival order,
    # the default) or "shortest_prompt" (admit the shortest effective
    # prompt first — lowest time-to-slot-free, the classic SJF latency
    # win; ties break FIFO)
    admission: str = "fifo"
    # anti-starvation bound for non-FIFO admission: once the queue head
    # has waited > this many policy units it is admitted first
    # regardless of ordering (None = pure policy, head can starve under
    # a stream of short prompts)
    admission_age_bound: Optional[float] = None


class Scheduler:
    """FIFO continuous-batching scheduler over one ``Engine``.

    Drive it with ``submit`` + repeated ``step`` (or ``run_open_loop``
    for a pre-generated arrival trace). Requires the engine's bucketed
    prefill mode — the token-mode oracle has no chunk seam to interleave
    through."""

    def __init__(self, engine: Engine, cfg: SchedulerConfig = None, *,
                 clock: Callable[[], float] = time.perf_counter,
                 spec=None):
        if engine.cfg.prefill_mode != "bucketed":
            raise ValueError(
                "scheduler requires prefill_mode='bucketed' (the token "
                "oracle has no chunk seam to interleave through)")
        self.engine = engine
        self.cfg = cfg or SchedulerConfig()
        # speculative decode: pass a SpecConfig (or a prebuilt
        # SpecDecoder over this engine) and every decode iteration runs
        # draft -> verify -> accept instead of one sequential step; the
        # rest of the scheduler is oblivious (RequestOutput streams
        # carry however many tokens a step emitted)
        self._spec = None
        if spec is not None:
            from repro.serving.speculative import SpecConfig, SpecDecoder
            if isinstance(spec, SpecDecoder):
                if spec.engine is not engine:
                    raise ValueError(
                        "SpecDecoder is bound to a different engine")
                self._spec = spec
            elif isinstance(spec, SpecConfig):
                self._spec = SpecDecoder(engine, spec)
            else:
                raise ValueError(
                    f"spec must be a SpecConfig or SpecDecoder, got "
                    f"{spec!r}")
        if self.cfg.admission not in ("fifo", "shortest_prompt"):
            raise ValueError(
                f"unknown admission policy {self.cfg.admission!r} "
                "(choices: 'fifo', 'shortest_prompt')")
        self.clock = clock
        self.waiting: Deque[Request] = deque()
        self.prefilling: List[Request] = []     # admission order
        self.running: Dict[int, Request] = {}   # slot -> request
        self.finished: List[Request] = []
        self.requests: List[Request] = []
        self._next_rid = 0
        self._last_result = None
        self.stats = {"steps": 0, "decode_steps": 0, "admitted": 0,
                      "preempted": 0, "rejected": 0,
                      "queue_depth_max": 0, "queue_depth_sum": 0,
                      "admission_reorders": 0,
                      "recompute_tokens_saved": 0}

    # ------------------------------------------------------------ intake
    def submit(self, prompt: Sequence[int],
               max_new_tokens: Optional[int] = None,
               eos_id: Optional[int] = None,
               arrival: Optional[float] = None, *,
               params: Optional[SamplingParams] = None) -> Request:
        """Queue a request (state WAITING). Per-request knobs arrive as a
        ``SamplingParams`` (``params=``) — its ``max_tokens`` / ``eos_id``
        / ``temperature`` / ``seed`` / ``spec_k`` are threaded to the
        engine at admission. The legacy ``max_new_tokens``/``eos_id``
        kwargs are accepted for one release under a DeprecationWarning
        and convert to the equivalent params bit-identically. ``arrival``
        defaults to the policy clock's now; open-loop traffic passes the
        trace's arrival time so queueing delay is measured against the
        *offered* load."""
        if max_new_tokens is not None and max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if params is not None:
            if max_new_tokens is not None or eos_id is not None:
                raise ValueError(
                    "pass params= or the legacy max_new_tokens/eos_id "
                    "kwargs, not both")
        else:
            if max_new_tokens is not None or eos_id is not None:
                warnings.warn(
                    "Scheduler.submit(max_new_tokens=..., eos_id=...) is "
                    "deprecated; pass params=SamplingParams(max_tokens="
                    "..., eos_id=...)", DeprecationWarning, stacklevel=2)
            params = SamplingParams(max_tokens=max_new_tokens,
                                    eos_id=eos_id)
        r = Request(rid=self._next_rid, prompt=list(prompt),
                    max_new_tokens=params.max_tokens, eos_id=params.eos_id,
                    params=params,
                    arrival=self.clock() if arrival is None else arrival,
                    wall_arrival=time.perf_counter())
        self._next_rid += 1
        self.requests.append(r)
        self.waiting.append(r)
        return r

    def idle(self) -> bool:
        return not (self.waiting or self.prefilling or self.running)

    @property
    def pj_per_token(self) -> Optional[float]:
        """Decode-phase CIM pJ per generated token, threaded from the
        last ``StepResult.pj_per_token`` (lazily priced; None before the
        first decode step or when the arch serves without the CIM path)."""
        if self._last_result is None:
            return None
        return self._last_result.pj_per_token

    # ----------------------------------------------------------- lifecycle
    def _finish(self, r: Request, reason: str, now: float,
                wall: float) -> None:
        r.state = FINISHED
        r.finish_reason = reason
        r.t_finish = now
        r.wall_finish = wall
        r.slot = None
        self.finished.append(r)

    def _admissible(self) -> int:
        """Slots this step's admission phase may claim (the hook the
        static-batching baseline overrides)."""
        return self.engine.free_slots()

    @staticmethod
    def _effective_prompt(r: Request) -> List[int]:
        return r.resume_prompt if r.resume_prompt is not None else r.prompt

    def _next_waiting(self, now: float) -> int:
        """Queue index of the next request to admit. FIFO by default;
        ``admission="shortest_prompt"`` picks the shortest effective
        prompt (ties break FIFO) — unless the queue head has aged past
        ``admission_age_bound``, in which case the head goes first
        (anti-starvation)."""
        if self.cfg.admission == "fifo" or len(self.waiting) <= 1:
            return 0
        bound = self.cfg.admission_age_bound
        if bound is not None and (now - self.waiting[0].arrival) > bound:
            return 0
        return min(range(len(self.waiting)),
                   key=lambda i: (len(self._effective_prompt(
                       self.waiting[i])), i))

    def _admit(self, now: float, wall: float) -> List[Request]:
        admitted = []
        budget = self._admissible()
        while self.waiting and budget > 0:
            idx = self._next_waiting(now)
            if idx != 0:
                self.stats["admission_reorders"] += 1
            r = self.waiting[idx]
            prompt = self._effective_prompt(r)
            if len(prompt) >= self.engine.cfg.max_ctx:
                del self.waiting[idx]
                if r.resume_prompt is not None:
                    # a resume that no longer fits: keep what it generated
                    self._finish(r, "length", now, wall)
                else:
                    self.stats["rejected"] += 1
                    self._finish(r, "rejected", now, wall)
                continue
            del self.waiting[idx]
            p = r.params
            if r.resume_prompt is not None and p.max_tokens is not None:
                # recompute resume: tokens generated before eviction are
                # part of the resume prompt, so the engine-side cap must
                # count only what is still owed
                p = p.replace(max_tokens=max(1, p.max_tokens
                                             - r.n_generated))
            r.slot = self.engine.begin_request(prompt, params=p)
            if r.resume_prompt is not None:
                # preemption recompute that the prefix cache absorbed:
                # the evicted lane's own boundary snapshots make the
                # re-prefill a (mostly) cache hit
                self.stats["recompute_tokens_saved"] += \
                    self.engine.adopted_prefix(r.slot)
            r.state = PREFILLING
            r.t_admit = now
            r.wall_admit = wall
            self.prefilling.append(r)
            self.stats["admitted"] += 1
            admitted.append(r)
            budget -= 1
        return admitted

    def _spend_prefill_budget(self, now: float,
                              key: Optional[jax.Array]) -> int:
        budget = self.cfg.prefill_token_budget
        spent = 0
        for r in list(self.prefilling):
            while self.engine.prefill_remaining(r.slot):
                left = None if budget is None else budget - spent
                if left is not None and left <= 0:
                    return spent
                got = self.engine.advance_prefill(r.slot, max_tokens=left)
                spent += got
            # prompt drained: first output token now, TTFT stamps here
            sub = None if key is None else jax.random.fold_in(key, r.rid)
            first = self.engine.finish_prefill(r.slot, key=sub)
            self.prefilling.remove(r)
            r.generated.append(first)
            r.t_first = now
            r.wall_first = time.perf_counter()
            if not self.engine.active[r.slot]:
                # engine finished it at prefill time (first token was the
                # EOS, or a one-token max_tokens cap) and freed the slot
                self._finish(r, self.engine.finish_reason(r.slot) or "eos",
                             now, r.wall_first)
            elif (r.max_new_tokens is not None
                  and r.n_generated >= r.max_new_tokens):
                # legacy fallback; params-carrying requests are capped
                # inside the engine and never reach this branch
                self.engine.release_slot(r.slot)
                self._finish(r, "length", now, r.wall_first)
            else:
                r.state = RUNNING
                self.running[r.slot] = r
        return spent

    def _decode(self, now: float, key: Optional[jax.Array]) -> dict:
        result = (self._spec.step(key) if self._spec is not None
                  else self.engine.step(key))
        self._last_result = result
        self.stats["decode_steps"] += 1
        wall = time.perf_counter()
        # the typed RequestOutput stream carries every token this
        # iteration emitted (several per lane under speculative decode)
        for out in result.outputs:
            r = self.running.get(out.slot)
            if r is not None:
                r.generated.extend(out.tokens)
        for slot in result.finished:
            # engine-side completion: EOS, max_tokens, or context
            # exhaustion — the engine records which. Slots with no bound
            # request (e.g. freed at prefill time and already accounted)
            # are skipped.
            r = self.running.pop(slot, None)
            if r is None:
                continue
            reason = self.engine.finish_reason(slot)
            if reason is None:
                eos = (r.eos_id if r.eos_id is not None
                       else self.engine.cfg.eos_id)
                reason = "eos" if (eos is not None and r.generated
                                   and r.generated[-1] == eos) else "ctx"
            self._finish(r, reason, now, wall)
        for slot, r in list(self.running.items()):
            # legacy fallback; params-carrying requests are capped inside
            # the engine and surface through result.finished above
            if (r.max_new_tokens is not None
                    and r.n_generated >= r.max_new_tokens):
                self.engine.release_slot(slot)
                del self.running[slot]
                self._finish(r, "length", now, wall)
        return dict(result)

    def _maybe_preempt(self, now: float) -> Optional[Request]:
        age = self.cfg.preempt_age
        if age is None or not self.waiting:
            return None
        if self.engine.free_slots() > 0:
            return None
        if (now - self.waiting[0].arrival) <= age:
            return None
        live = self.prefilling + list(self.running.values())
        if not live:
            return None
        victim = max(live, key=lambda r: r.t_admit)   # LIFO: newest admit
        self.engine.release_slot(victim.slot)
        if victim in self.prefilling:
            self.prefilling.remove(victim)
        else:
            del self.running[victim.slot]
        victim.state = PREEMPTED
        victim.slot = None
        victim.preemptions += 1
        # recompute resume: re-prefill everything emitted so far, which
        # reconstructs the evicted cache exactly (greedy streams are
        # preemption-invariant — tested)
        victim.resume_prompt = list(victim.prompt) + list(victim.generated)
        self.waiting.append(victim)   # back of the queue: FIFO fairness
        self.stats["preempted"] += 1
        return victim

    # ------------------------------------------------------------- step
    def step(self, key: Optional[jax.Array] = None) -> dict:
        """One scheduler iteration: preempt (if starving) → admit →
        budgeted prefill → one decode dispatch for the running lanes.
        Returns a summary dict (admitted/prefilled/decoded/finished
        counts) for observability; request objects carry the full
        accounting. Pass ``key`` to sample (split per use; greedy
        otherwise)."""
        now = self.clock()
        wall = time.perf_counter()
        self.stats["steps"] += 1
        depth = len(self.waiting)
        self.stats["queue_depth_max"] = max(self.stats["queue_depth_max"],
                                            depth)
        self.stats["queue_depth_sum"] += depth

        self._maybe_preempt(now)
        k_fill = k_dec = None
        if key is not None:
            k_fill, k_dec = jax.random.split(key)
        admitted = self._admit(now, wall)
        n_before = len(self.finished)
        spent = self._spend_prefill_budget(now, k_fill)
        decoded = self._decode(now, k_dec) if self.running else {}
        return {
            "admitted": [r.rid for r in admitted],
            "prefill_tokens": spent,
            "decoded": decoded,
            "finished": [r.rid for r in self.finished[n_before:]],
            "queue_depth": depth,
        }

    # ----------------------------------------------------------- metrics
    def metrics(self, slo_ttft: Optional[float] = None) -> dict:
        """Aggregate serving metrics over finished requests.

        ``slo_ttft`` is a policy-clock TTFT bound; requests over it (or
        rejected) are excluded from goodput. Latency percentiles are
        reported in wall ms (machine-dependent) and policy units
        (deterministic under ``StepClock``)."""
        done = [r for r in self.finished if r.finish_reason != "rejected"]
        ttft_w = [r.ttft_wall for r in done if r.ttft_wall is not None]
        tpot_w = [r.tpot_wall for r in done if r.tpot_wall is not None]
        ttft_p = [r.ttft for r in done if r.ttft is not None]
        in_slo = [r for r in done
                  if slo_ttft is None
                  or (r.ttft is not None and r.ttft <= slo_ttft)]
        good_tokens = sum(r.n_generated for r in in_slo)
        t0 = min((r.arrival for r in self.requests), default=0.0)
        t1 = max((r.t_finish for r in done), default=t0)
        w0 = min((r.wall_arrival for r in self.requests), default=0.0)
        w1 = max((r.wall_finish for r in done), default=w0)
        makespan = max(t1 - t0, 1e-12)
        wall_s = max(w1 - w0, 1e-12)

        def pct(xs, q, scale=1.0):
            return float(np.percentile(xs, q) * scale) if xs else None

        pj = self.pj_per_token
        out = {
            "completed": len(done),
            "completed_in_slo": len(in_slo),
            "rejected": self.stats["rejected"],
            "preempted": self.stats["preempted"],
            "sched_steps": self.stats["steps"],
            "decode_steps": self.stats["decode_steps"],
            "prefill_dispatches": self.engine.stats["prefill_dispatches"],
            "queue_depth_max": self.stats["queue_depth_max"],
            "queue_depth_mean": (self.stats["queue_depth_sum"]
                                 / max(1, self.stats["steps"])),
            "generated_tokens": sum(r.n_generated for r in done),
            "goodput_tokens": good_tokens,
            "makespan_steps": makespan,
            "goodput_tok_per_step": good_tokens / makespan,
            "wall_s": wall_s,
            "goodput_tok_s": good_tokens / wall_s,
            "ttft_p50_ms": pct(ttft_w, 50, 1e3),
            "ttft_p99_ms": pct(ttft_w, 99, 1e3),
            "tpot_p50_ms": pct(tpot_w, 50, 1e3),
            "tpot_p99_ms": pct(tpot_w, 99, 1e3),
            "ttft_p50_steps": pct(ttft_p, 50),
            "ttft_p99_steps": pct(ttft_p, 99),
            "pj_per_token": pj,
            "energy_pj": (None if pj is None
                          else pj * sum(r.n_generated for r in done)),
            # prefill work actually dispatched vs absorbed by the prefix
            # cache (saved = adopted tokens; both exact under StepClock)
            "prefill_tokens_dispatched": self.engine.stats["prefill_tokens"],
            "prefill_tokens_saved": self.engine.stats["prefix_hit_tokens"],
            "recompute_tokens_saved": self.stats["recompute_tokens_saved"],
            "admission_reorders": self.stats["admission_reorders"],
            # speculative-decode counters (all 0 without spec=): exact
            # under StepClock, like the other scheduling leaves
            "draft_dispatches": self.engine.stats["draft_dispatches"],
            "verify_dispatches": self.engine.stats["verify_dispatches"],
            "repair_dispatches": self.engine.stats["repair_dispatches"],
            "spec_steps": self.engine.stats["spec_steps"],
            "spec_tokens": self.engine.stats["spec_tokens"],
            "accepted_tokens_per_step": (
                self.engine.stats["spec_tokens"]
                / max(1, self.engine.stats["spec_steps"])),
        }
        pc = self.engine.prefix_cache
        if pc is not None:
            out.update({
                "prefix_hits": pc.stats["hits"],
                "prefix_misses": pc.stats["misses"],
                "prefix_inserts": pc.stats["inserts"],
                "prefix_evictions": pc.stats["evictions"],
                "prefix_bytes": pc.stats["bytes"],
                "prefix_hit_rate": pc.hit_rate(),
            })
        return out


class StaticBatchScheduler(Scheduler):
    """The naive blocking-admission baseline: admission waits for the
    WHOLE previous batch to drain (classic static batching — the
    pre-continuous-batching world), and every admitted prompt prefills
    to completion before any decode resumes. Same engine, same
    dispatches per request; freed slots simply idle while stragglers
    finish. The traffic bench measures continuous batching against
    this."""

    def __init__(self, engine: Engine, cfg: SchedulerConfig = None, *,
                 clock: Callable[[], float] = time.perf_counter,
                 spec=None):
        cfg = dataclasses.replace(cfg or SchedulerConfig(),
                                  prefill_token_budget=None,
                                  preempt_age=None)
        super().__init__(engine, cfg, clock=clock, spec=spec)

    def _admissible(self) -> int:
        if self.running or self.prefilling:
            return 0
        return self.engine.free_slots()


class StepClock:
    """Virtual policy clock in *dispatch-cost* units. ``run_open_loop``
    ticks it by the number of compiled dispatches the scheduler step
    issued (each decode step and each prefill chunk = 1 unit; an idle
    wait between arrivals = 1 unit), so virtual time charges a blocking
    prefill burst what it actually costs the device instead of hiding it
    inside one "step". Under it every scheduling decision — admission
    order, chunk slicing, dispatch and completion counts — is a pure
    function of the (seeded) traffic, so the bench's count leaves can be
    gated with exact equality across machines while wall-clock latency
    is measured alongside."""

    def __init__(self, dt: float = 1.0):
        self.t = 0.0
        self.dt = dt

    def now(self) -> float:
        return self.t

    def tick(self, dt: Optional[float] = None) -> None:
        self.t += self.dt if dt is None else dt * self.dt


def _dispatch_count(eng: Engine) -> int:
    """Total compiled dispatches the engine has issued — the virtual
    clock's cost unit. Speculative draft/verify/repair dispatches cost a
    clock unit each, exactly like a decode step or a prefill chunk (they
    are the same-shaped device work), so spec's latency win shows up as
    fewer clock units per emitted token."""
    s = eng.stats
    return (s["prefill_dispatches"] + s["decode_steps"]
            + s["draft_dispatches"] + s["verify_dispatches"]
            + s["repair_dispatches"])


@dataclasses.dataclass
class TrafficRequest:
    arrival: float
    prompt: List[int]
    max_new_tokens: int


def synth_traffic(n: int, rate: float, *, seed: int, vocab_size: int,
                  prompt_len=(8, 48), out_len=(4, 16)) -> List[TrafficRequest]:
    """Seeded open-loop Poisson traffic: exponential inter-arrivals at
    ``rate`` requests per policy-time unit, uniform prompt/output length
    distributions (inclusive bounds), uniform random token ids.

    The arrival *pattern* is rate-invariant: unit-rate gaps are drawn
    first and scaled by ``1/rate``, so sweeping ``rate`` offers the same
    request sequence faster or slower — goodput curves across rates are
    then directly comparable."""
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(1.0, size=n)) / rate
    plens = rng.randint(prompt_len[0], prompt_len[1] + 1, size=n)
    olens = rng.randint(out_len[0], out_len[1] + 1, size=n)
    return [
        TrafficRequest(
            arrival=float(arrivals[i]),
            prompt=[int(t) for t in
                    rng.randint(1, vocab_size, size=int(plens[i]))],
            max_new_tokens=int(olens[i]))
        for i in range(n)
    ]


def synth_shared_prefix_traffic(
        n: int, rate: float, *, seed: int, vocab_size: int,
        n_prefixes: int = 4, prefix_len: int = 24, zipf_s: float = 1.1,
        user_len=(4, 16), out_len=(4, 16)) -> List[TrafficRequest]:
    """Seeded Poisson traffic whose prompts share system prompts: each
    request draws one of ``n_prefixes`` fixed ``prefix_len``-token
    system prompts with Zipf(``zipf_s``) rank probabilities (a few
    prompts dominate, like production templates do) and appends a unique
    uniform-random user suffix. Arrival gaps are rate-invariant exactly
    as in ``synth_traffic``. Keep ``prefix_len`` a multiple of the
    engine's ``prefill_bucket_min`` so the shared part is a cacheable
    chunk boundary."""
    rng = np.random.RandomState(seed)
    pool = [[int(t) for t in rng.randint(1, vocab_size, size=prefix_len)]
            for _ in range(n_prefixes)]
    probs = 1.0 / np.arange(1, n_prefixes + 1) ** zipf_s
    probs /= probs.sum()
    arrivals = np.cumsum(rng.exponential(1.0, size=n)) / rate
    picks = rng.choice(n_prefixes, size=n, p=probs)
    ulens = rng.randint(user_len[0], user_len[1] + 1, size=n)
    olens = rng.randint(out_len[0], out_len[1] + 1, size=n)
    return [
        TrafficRequest(
            arrival=float(arrivals[i]),
            prompt=pool[int(picks[i])] + [
                int(t) for t in rng.randint(1, vocab_size,
                                            size=int(ulens[i]))],
            max_new_tokens=int(olens[i]))
        for i in range(n)
    ]


def run_open_loop(sched: Scheduler, traffic: Sequence[TrafficRequest], *,
                  tick: Optional[Callable[[float], None]] = None,
                  max_steps: int = 200_000,
                  key: Optional[jax.Array] = None) -> int:
    """Drive ``sched`` through an open-loop arrival trace until every
    request finishes: release arrivals whose time has come, step, tick
    the virtual clock (or sleep briefly on a wall clock while idle).

    ``tick`` (typically ``StepClock.tick``) receives the step's
    dispatch cost — the number of compiled dispatches (prefill chunks +
    decode) the step issued, minimum 1 — so virtual time is charged per
    unit of device work, not per scheduler iteration; an idle wait
    between arrivals costs 1. Returns the number of scheduler steps
    taken."""
    i, steps = 0, 0
    while True:
        now = sched.clock()
        while i < len(traffic) and traffic[i].arrival <= now:
            t = traffic[i]
            sched.submit(t.prompt, arrival=t.arrival,
                         params=SamplingParams(
                             max_tokens=t.max_new_tokens))
            i += 1
        if i >= len(traffic) and sched.idle():
            return steps
        if sched.idle():
            # between arrivals: advance time without burning dispatches
            if tick is not None:
                tick(1.0)
            else:
                time.sleep(1e-4)
            continue
        key, sub = ((None, None) if key is None
                    else jax.random.split(key))
        before = _dispatch_count(sched.engine)
        sched.step(sub)
        after = _dispatch_count(sched.engine)
        steps += 1
        if tick is not None:
            tick(max(1.0, float(after - before)))
        if steps >= max_steps:
            raise RuntimeError(
                f"open-loop run exceeded {max_steps} steps with "
                f"{len(sched.waiting)} waiting / {len(sched.running)} "
                "running — traffic does not drain")


def run_closed_loop(sched: Scheduler, traffic: Sequence[TrafficRequest], *,
                    concurrency: int,
                    tick: Optional[Callable[[float], None]] = None,
                    max_steps: int = 200_000,
                    key: Optional[jax.Array] = None) -> int:
    """Drive ``sched`` closed-loop at fixed concurrency: keep exactly
    ``concurrency`` requests in flight (submitted minus finished),
    topping up from ``traffic`` (arrival times ignored — each request
    "arrives" the moment a virtual client submits it) the instant one
    completes, until the trace is exhausted and drained. The classic
    benchmark-client model, complementary to ``run_open_loop``'s
    offered-load one: latency here measures the service at a fixed
    population instead of under a fixed arrival rate. Ticks the clock
    per dispatch exactly like the open-loop driver; returns the number
    of scheduler steps taken."""
    if concurrency < 1:
        raise ValueError("concurrency must be >= 1")
    i, steps = 0, 0
    while True:
        while i < len(traffic) and (i - len(sched.finished)) < concurrency:
            t = traffic[i]
            sched.submit(t.prompt, params=SamplingParams(
                max_tokens=t.max_new_tokens))
            i += 1
        if i >= len(traffic) and sched.idle():
            return steps
        key, sub = ((None, None) if key is None
                    else jax.random.split(key))
        before = _dispatch_count(sched.engine)
        sched.step(sub)
        after = _dispatch_count(sched.engine)
        steps += 1
        if tick is not None:
            tick(max(1.0, float(after - before)))
        if steps >= max_steps:
            raise RuntimeError(
                f"closed-loop run exceeded {max_steps} steps with "
                f"{len(sched.waiting)} waiting / {len(sched.running)} "
                "running — traffic does not drain")
