"""Self-speculative multi-token decode with exact GR-MAC verification.

Sequential decode is one full-batch dispatch per token — the last TTLT
lever after chunked prefill (PR 2) and prefix-cache reuse (PR 9). This
module drafts ``k - 1`` tokens per iteration with a *cheap configuration
of the same model* and verifies all of them in ONE chunked dispatch
through the exact serving path, keeping the longest accepted prefix.
Because drafting is self-speculative (same weights, same cache — only
the CIM numerics config differs), there is no second model, no draft
prefill, and no separate draft cache to manage.

Draft policies (``SpecConfig.draft``)
-------------------------------------
* ``"digital"`` — the same arch with ``cim.with_mode("off")``: drafting
  runs the plain digital matmul path (cheap in pJ terms vs grmac, and
  the natural drafter the ROADMAP names).
* ``"self"``    — the target arch itself: drafts are exact, so greedy
  acceptance is 100% (structurally — the draft executable IS the serving
  decode executable). The deterministic always-accept cell the bench
  exact-gates.
* a ``site_overrides`` dict / ``CIMConfig`` / full ``ArchConfig`` — an
  aggressive low-energy deployment point straight off the PR-5 Pareto
  front, drafting through analog numerics at a fraction of the energy.

Acceptance rule
---------------
Greedy (the bit-exactness story): the verify chunk is
``[pending_token, d_1 .. d_{k-1}]`` fed through the *existing* bucketed
prefill executable — ``models.prefill_step`` returns per-position argmax
ids, so position ``j``'s id is the target model's greedy continuation
given the lane's context plus drafts ``d_1..d_j``. The accepted count is
``a = 1 + (length of the longest draft prefix matching those ids)``; the
emitted tokens are the ids' first ``a`` entries (accepted drafts are
*equal* to them; the last one is the correction at the first mismatch,
or the free bonus token after full acceptance). By induction each
emitted token is conditioned only on accepted-and-therefore-correct
inputs, so greedy speculative streams are **bit-identical to sequential
decode** — across attn / rglru / ssm / moe, regardless of how bad the
drafter is (tested per family).

Sampled: drafts stay greedy, i.e. a *delta* proposal at the draft
argmax, so the standard speculative rejection rule reduces to: accept
``d_j`` with probability ``p(d_j)`` under the target softmax at that
position; at the first rejection resample from ``p`` with the rejected
token's probability zeroed (the renormalized residual), else sample the
bonus token from the next-position target distribution. This is
unbiased — the emitted stream is distributed exactly as sequential
sampling — but not bit-identical to it (different PRNG event order);
``engine._verify_raw`` runs the whole rule on device behind the same
seam, one packed fetch. Mixed batches work: lanes with temperature 0
get exact greedy acceptance inside the sampled executable.

Rollback semantics (the recurrent-arch part)
--------------------------------------------
Drafting and verifying write cache state for tokens that may be
rejected. What needs rolling back is exactly what a *stale write* can
corrupt:

* **Global attention KV: nothing.** Rows past a lane's committed length
  are causally masked (decode reads ``slot <= idx``, prefill masks
  ``q_pos >= k_pos``) and are positionally overwritten before the
  committed length ever reaches them — rejected-token rows are
  invisible by construction (MoE FFNs are stateless, so grok rides the
  same argument).
* **Local-attention ring buffers: snapshot/restore.** A ring write at
  ``pos % window`` *destroys a valid older row* — masking cannot undo
  that, so rings roll back.
* **RG-LRU / SSM recurrent + conv states: snapshot/restore.** The
  whole point of ISSUE/ROADMAP item 3 — the recurrent state mutates on
  every pass and has no positional addressing to hide behind. It is
  also tiny (one (B, D)-ish tensor per layer), which is what makes
  self-speculation on recurrent archs cheap here while GPU serving
  stacks mostly skip them.

``Engine.spec_snapshot`` captures references to exactly those subtrees
(jax arrays are immutable — O(1), no copy); ``spec_restore`` is a
per-lane device-side where-merge. The step then is::

    S0 = snapshot → draft k-1 greedy decode dispatches (draft lanes
    masked) → restore S0 (undo draft pollution) → ONE verify chunk
    dispatch → host acceptance → for partially-accepted live lanes:
    restore S0 again + ONE repair dispatch re-feeding each lane's
    accepted prefix (per-lane ``chunk_lengths``, 0 = bitwise frozen) at
    its pre-verify offset.

The repair dispatch reuses the same bucket executable and fetches
nothing (acceptance already knows every token) — ``invariants.
run_spec_invariants`` proves both the zero-new-compiles claim and the
fetch arithmetic ``fetches == admissions + drafts + verifies``. For
pure global-attention archs the snapshot is empty and restore/repair
are skipped entirely: speculation there is rollback-free.

Energy accounting (``price_speculation``)
-----------------------------------------
The CostLedger answers whether speculation is a pJ/token win, not just
a latency win. Convention: marginal per-lane energy, matching
``Engine.energy_per_token`` — sequential decode costs
``price_ledger(trace_decode(arch), 1)`` pJ/token; a draft dispatch
costs the draft arch's decode pJ/token (a digital draft is priced at
the target ledger's *conventional* fJ/op); a verify or repair dispatch
costs ``price_ledger(trace_prefill(arch, bucket), bucket) × bucket``
(the bucket is padded, and padded positions burn real energy — the
honest denominator). Then::

    spec_pJ/accepted = (draft_dispatches × draft_pJ
                        + (verify + repair dispatches) × chunk_pJ)
                       / accepted_tokens

measured counters in, deterministic seeded-MC ENOB pricing out — the
bench gates the boolean verdict exactly and reports the floats.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import costs
from repro.core.cim_config import CIMConfig
from repro.serving.engine import Engine, RequestOutput, StepResult

__all__ = ["SpecConfig", "SpecDecoder", "draft_arch_for",
           "price_speculation"]


DraftPolicy = Union[str, dict, CIMConfig, ArchConfig]


def draft_arch_for(arch: ArchConfig, draft: DraftPolicy) -> ArchConfig:
    """Resolve a ``SpecConfig.draft`` policy to the draft ArchConfig.
    Every policy keeps the model itself (weights, cache layout) — only
    the CIM numerics config may differ."""
    if isinstance(draft, str):
        if draft == "self":
            return arch
        if draft == "digital":
            return arch.replace(cim=arch.cim.with_mode("off"))
        raise ValueError(
            f"unknown draft policy {draft!r} (choices: 'self', 'digital', "
            "a site_overrides dict, a CIMConfig, or an ArchConfig)")
    if isinstance(draft, dict):
        return arch.replace(cim=arch.cim.with_site_overrides(draft))
    if isinstance(draft, CIMConfig):
        return arch.replace(cim=draft)
    if isinstance(draft, ArchConfig):
        if (draft.n_layers, draft.d_model, draft.block_pattern) != \
                (arch.n_layers, arch.d_model, arch.block_pattern):
            raise ValueError(
                "draft ArchConfig must be the same model as the target "
                "(self-speculation shares weights and cache)")
        return draft
    raise ValueError(f"unsupported draft policy: {draft!r}")


@dataclasses.dataclass
class SpecConfig:
    """Speculative-decode policy. ``k`` is the default number of tokens
    scored per lane per iteration (1 pending + ``k - 1`` drafts; a
    request's ``SamplingParams.spec_k`` overrides it, ``spec_k=1`` opts
    the request out). ``draft`` picks the drafter — see module
    docstring."""
    k: int = 4
    draft: DraftPolicy = "digital"

    def __post_init__(self):
        if self.k < 2:
            raise ValueError(f"SpecConfig.k must be >= 2, got {self.k}")


class SpecDecoder:
    """Drop-in multi-token replacement for ``Engine.step``: each
    ``step()`` emits between 1 and ``k`` tokens per live lane through
    draft → verify → accept → (restore + repair). The scheduler drives
    it exactly like the engine (``Scheduler(..., spec=...)``).

    ``draft_fn`` is a test seam: a callable ``(cur_tokens (B,), t) ->
    (B,) int32`` replacing the draft dispatches entirely (deterministic
    forced mismatches for the rollback tests). With it, no draft
    pollution ever reaches the cache, so the pre-verify restore is
    skipped."""

    def __init__(self, engine: Engine, cfg: Optional[SpecConfig] = None,
                 *, draft_fn: Optional[Callable] = None):
        self.engine = engine
        self.cfg = cfg or SpecConfig()
        self.draft_arch = draft_arch_for(engine.arch, self.cfg.draft)
        self.draft_fn = draft_fn

    # ----------------------------------------------------------- stepping
    def _lane_budgets(self) -> np.ndarray:
        """Per-lane chunk sizes k_i: the request's spec_k (or the default
        k), capped so the verify never writes past max_ctx and never
        emits past the request's max_tokens; floor 1 (sequential)."""
        eng = self.engine
        k_arr = np.ones(eng.cfg.batch_slots, np.int64)
        for s in np.where(eng.active)[0]:
            k = int(eng._spec_k[s]) if eng._spec_k[s] >= 1 else self.cfg.k
            k = min(k, eng.cfg.max_ctx - int(eng.lengths[s]))
            if eng._max_toks[s] >= 0:
                k = min(k, int(eng._max_toks[s] - eng._emitted[s]))
            k_arr[s] = max(1, k)
        return k_arr

    def step(self, key: Optional[jax.Array] = None) -> StepResult:
        """One speculative iteration over every active slot. Returns a
        ``StepResult`` shaped exactly like ``Engine.step``'s — the dict
        maps each live slot to its *last* token this step, while
        ``outputs`` carries every emitted token per request — so
        scheduler/bench consumers are oblivious to how many tokens a
        step produced. Falls through to plain ``Engine.step`` when no
        lane has speculation budget (all k_i == 1)."""
        eng = self.engine
        if not eng.active.any():
            return eng.step(key)
        k_arr = self._lane_budgets()
        if int(k_arr.max()) <= 1:
            return eng.step(key)
        pending, outputs = eng._drain_pending()
        act = eng.active.copy()
        base_len = eng.lengths.copy()
        spec = act & (k_arr > 1)
        n_draft = int(k_arr.max()) - 1

        # --- draft: n_draft greedy decode dispatches on the shared cache
        snap = eng.spec_snapshot()
        cur = eng._last_host.copy()
        drafts = np.zeros((n_draft, eng.cfg.batch_slots), np.int32)
        drafted = False
        for t in range(n_draft):
            mask = spec & ((k_arr - 1) > t)
            if self.draft_fn is not None:
                ids = np.asarray(self.draft_fn(cur.copy(), t), np.int32)
            else:
                offs = np.minimum(base_len + t, eng.cfg.max_ctx - 1)
                ids = eng.draft_step(self.draft_arch, cur, mask, offs)
                drafted = True
            drafts[t] = np.where(mask, ids, 0)
            cur = np.where(mask, ids, cur)
        if drafted:
            # undo draft-numerics pollution of rings/recurrent states
            # before the exact verify re-feeds the same positions
            eng.spec_restore(snap, spec)

        # --- verify: ONE chunked dispatch through the exact target path
        kmax = int(k_arr.max())
        chunk = np.zeros((eng.cfg.batch_slots, kmax), np.int32)
        chunk[:, 0] = eng._last_host
        for t in range(n_draft):
            chunk[:, t + 1] = drafts[t]
        lens = np.where(act, k_arr, 0).astype(np.int32)
        eff = eng._effective_temps(key)
        if bool((eff[act] > 0).any()):
            emitted_arr, a_arr = eng.verify_chunk_sampled(chunk, lens, key)
            a_arr = a_arr.astype(np.int64)
        else:
            tgt = eng.verify_chunk(chunk, lens)
            a_arr = np.zeros(eng.cfg.batch_slots, np.int64)
            emitted_arr = np.zeros_like(chunk)
            for s in np.where(act)[0]:
                m = 0
                while m < k_arr[s] - 1 and chunk[s, m + 1] == tgt[s, m]:
                    m += 1
                a_arr[s] = m + 1
                # accepted drafts ARE the target ids; entry m is the
                # correction (first mismatch) or the bonus (all matched)
                emitted_arr[s, :m + 1] = tgt[s, :m + 1]

        # --- commit accepted prefixes; collect repairs
        out = {}
        finished = list(pending)
        repair_mask = np.zeros(eng.cfg.batch_slots, bool)
        repair_lens = np.zeros(eng.cfg.batch_slots, np.int32)
        total = 0
        for s in np.where(act)[0]:
            s = int(s)
            a = int(a_arr[s])
            toks_s = [int(x) for x in emitted_arr[s, :a]]
            reason = None
            eos = int(eng._eos[s])
            if eos >= 0 and eos in toks_s:
                a = toks_s.index(eos) + 1
                toks_s = toks_s[:a]
                reason = "eos"
            eng.tokens[s].extend(toks_s)
            eng.lengths[s] += a
            eng._emitted[s] += a
            eng._last_host[s] = toks_s[-1]
            out[s] = toks_s[-1]
            total += a
            if reason is None:
                if 0 <= eng._max_toks[s] <= eng._emitted[s]:
                    reason = "length"
                elif eng.lengths[s] >= eng.cfg.max_ctx:
                    reason = "ctx"
            if reason is not None:
                eng._finish_reason[s] = reason
                eng.active[s] = False
                finished.append(s)
            elif a < int(k_arr[s]):
                # live lane accepted a strict prefix: its recurrent/ring
                # state ran past the commit point — roll back + repair.
                # (Finished lanes skip this: a freed slot is zeroed on
                # its next claim anyway.)
                repair_mask[s] = True
                repair_lens[s] = a
            outputs.append(RequestOutput(
                slot=s, tokens=toks_s, finished=reason is not None,
                finish_reason=reason, _energy_fn=eng._pj_per_token))
        if repair_mask.any() and snap:
            eng.spec_restore(snap, repair_mask)
            eng.repair_chunk(chunk, repair_lens, base_len)
        eng.stats["spec_steps"] += 1
        eng.stats["spec_tokens"] += total
        return StepResult(out, finished, eng._pj_per_token,
                          outputs=outputs)


# ------------------------------------------------------------------ energy
def price_speculation(arch: ArchConfig, draft_arch: ArchConfig,
                      stats: dict, verify_bucket: int, *,
                      seed: int = 0, n_cols: int = 1 << 11) -> dict:
    """pJ/accepted-token of draft+verify vs sequential decode, priced
    from *measured* dispatch counters (``Engine.stats``) and the
    CostLedger traces of the real model fns — the module docstring
    carries the conventions. Deterministic for fixed (arch, counters,
    seed), so the bench exact-gates the ``energy_win`` verdict."""
    if not arch.cim.enabled:
        return {"enabled": False}
    dec = costs.price_ledger(costs.trace_decode(arch), 1,
                             seed=seed, n_cols=n_cols)
    pre = costs.price_ledger(
        costs.trace_prefill(arch, bucket=verify_bucket), verify_bucket,
        seed=seed, n_cols=n_cols)
    if draft_arch.cim.enabled:
        draft_pj = costs.price_ledger(costs.trace_decode(draft_arch), 1,
                                      seed=seed,
                                      n_cols=n_cols)["pj_per_token"]
    else:
        # digital draft: the same ops at the conventional (digital)
        # energy point of the target's ledger
        draft_pj = dec["conventional_pj_per_token"]
    chunk_pj = pre["pj_per_token"] * verify_bucket
    accepted = max(1, int(stats["spec_tokens"]))
    steps = max(1, int(stats["spec_steps"]))
    spec_pj = (stats["draft_dispatches"] * draft_pj
               + (stats["verify_dispatches"]
                  + stats["repair_dispatches"]) * chunk_pj) / accepted
    return {
        "enabled": True,
        "verify_bucket": verify_bucket,
        "seq_pj_per_token": dec["pj_per_token"],
        "draft_pj_per_dispatch": draft_pj,
        "verify_pj_per_dispatch": chunk_pj,
        "spec_pj_per_accepted_token": spec_pj,
        "accepted_tokens_per_step": stats["spec_tokens"] / steps,
        "energy_win": bool(spec_pj < dec["pj_per_token"]),
    }
