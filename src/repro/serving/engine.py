"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch with chunked, length-bucketed prefill and on-device sampling.

Prefill (``add_request``) pads each prompt chunk to a power-of-two bucket
and runs it through ``models.prefill_step`` — one compiled dispatch per
bucket (so O(ceil(len/bucket_max)) dispatches per prompt, vs one per token
in the legacy ``prefill_mode="token"`` path), with the compile cache
bounded by the O(log max_ctx) distinct bucket lengths per arch. Lanes not
being prefilled are frozen inside the dispatch (length 0), so no host-side
cache merging happens on the prefill path at all.

The **first output token is sampled from the prefill itself**: both
prefill modes adopt the last-valid-token logits (``prefill_step`` returns
them; the legacy token path's final dispatch produces the same ids), so
the first decode step feeds the first *generated* token — the seed-era
re-feed of the last prompt token, which wrote its K/V at positions len-1
AND len, is gone. ``add_request`` therefore appends one generated token
before returning (pass ``key`` to sample it when ``temperature > 0``).

Decode (``step``) is a single jit'd function over the whole batch that also
performs the per-lane cache merge *and* token selection (greedy argmax or
temperature-categorical) on device: logits never leave the device — the
host sees exactly one device→host transfer of a ``(batch_slots,)`` int32
array of sampled ids per step.

Per-token CIM energy accounting: ``energy_report`` prices the
``core.costs.CostLedger`` built by a shape-only trace of the *real* model
functions (prefill / decode / train phases) at each site's resolved design
— no hand-derived MAC census — and ``Engine.step`` /
``Engine.energy_per_token`` surface decode-phase pJ per generated token
next to the serving stats. The underlying required-ENOB Monte-Carlo is
memoized per design point (see ``core.costs.design_energy_fj``).

Machine-checked invariants
--------------------------
Two hot-path properties are enforced by ``repro.analysis.invariants``
(CI audit lane + tests/test_serving_invariants.py), not just documented:

1. **Compile budget**: at most one compilation per (arch, sampling mode)
   decode executable and per (arch, bucket) prefill executable, shared by
   every Engine via the module-level ``_decode_fn``/``_prefill_fn`` lru
   caches. A second trace of the same key means a retracing regression
   (the PR-1 recompile bug).
2. **One transfer per decode step**: every device→host crossing routes
   through ``Engine._fetch`` — one ``(batch_slots,)`` int32 array per
   ``step`` (and per prefill first-token selection). Adding a second
   transfer to the hot path fails the harness.

The seams the harness instruments are ``_decode_raw``/``_prefill_raw``
(the unjitted step bodies), ``_compiled_decode``/``_compiled_prefill``
(the per-engine dispatch points), and ``_fetch``; keep new hot-path code
flowing through them.

Incremental prefill (the scheduler seam)
----------------------------------------
``add_request`` runs a prompt's whole prefill in one blocking burst. The
continuous-batching scheduler (``repro.serving.scheduler``) instead needs
to drain prefills *chunk by chunk between decode steps*, so the engine
exposes the burst's three phases as first-class methods:

* ``begin_request(prompt)``  — claim + validate a slot (the lane is
  reserved but NOT in the decode batch yet);
* ``advance_prefill(slot, max_tokens)`` — one bucketed chunk dispatch of
  at most ``max_tokens`` prompt tokens (same power-of-two bucket
  executables as ``add_request``: no new compiles);
* ``finish_prefill(slot, key)`` — select the first output token from the
  last chunk's logits and activate the lane for decode.

``add_request`` is now literally ``begin → advance-until-drained →
finish``, so both entry points share one code path and stay equivalent.
``release_slot`` frees a lane mid-flight (scheduler-side stops at
``max_new_tokens``, preemption); ``free_slots`` is the admission-control
counter (active and mid-prefill lanes both count as occupied).

Prefix cache (``repro.serving.prefix_cache``): with
``ServeConfig.prefix_cache_bytes`` set (or an explicit ``PrefixCache``
passed to the constructor), ``begin_request`` adopts the longest cached
prefix into the claimed lane device-side and queues only the suffix, and
``advance_prefill`` stores new chunk-aligned boundary snapshots — both
composing with the existing bucket executables (no new compiles) and
the one-transfer invariant (nothing crosses to the host). The module
docstring of ``prefix_cache`` carries the full design note.

Sampling contract: ``temperature > 0`` samples **only when a PRNG key is
passed** — ``add_request``/``finish_prefill`` with ``temperature > 0``
and no ``key`` fall back to greedy argmax *with an explicit
``UserWarning``* (``step`` applies the same key-gated rule silently,
since it is called once per token; pass ``key=`` everywhere to sample).
Exception: a request with ``SamplingParams.seed`` set derives its lane
keys from its own seed (``fold_in(PRNGKey(seed), event_counter)`` inside
the executable) and therefore samples even without a per-step key — and
reproducibly, independent of slot placement and co-batched traffic.

Request API (``repro.serving.params``): per-request knobs enter through
``SamplingParams`` — ``add_request(prompt, params=...)`` /
``begin_request(prompt, params=...)`` — covering temperature, seed,
eos_id, max_tokens (enforced here: the lane frees itself with finish
reason ``"length"``) and the speculative ``spec_k``. The legacy
``eos_id=`` kwarg is still accepted for one release under a
``DeprecationWarning`` and behaves bit-identically. ``StepResult`` still
quacks like the old slot->token dict, but carries a typed
``outputs`` list of per-request ``RequestOutput`` records
(tokens-this-step, finished, finish_reason, lazy pJ/token).

Speculative seams (``repro.serving.speculative`` is the orchestrator;
the design note lives there): ``spec_snapshot``/``spec_restore`` capture
and lane-mask-restore the rollback-sensitive cache subtrees (local-attn
rings + RG-LRU/SSM recurrent states — global-attn KV needs none, stale
rows stay causally masked until overwritten), ``draft_step`` is one
greedy decode dispatch of a cheap same-weights draft arch against the
shared cache, ``verify_chunk`` reuses the *existing* bucketed prefill
executables as the exact greedy verifier (``prefill_step`` returns
per-position argmax ids precisely for this — zero new compiles),
``verify_chunk_sampled`` runs the rejection-rule acceptance on device,
and ``repair_chunk`` re-feeds the accepted prefix after a restore
(no fetch — acceptance already knows the tokens).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import costs
from repro.models import decode_step, forward, init_cache, prefill_step
from repro.serving.params import RequestOutput, SamplingParams
from repro.serving.prefix_cache import (
    PrefixCache,
    restore_slot,
    snapshot_slot,
)

__all__ = ["ServeConfig", "Engine", "StepResult", "SamplingParams",
           "RequestOutput", "energy_report"]


class StepResult(dict):
    """``Engine.step`` result: slot id -> last sampled token (dict, as
    before), plus ``finished`` — the slot ids freed this step (per-slot
    EOS, ``max_tokens``, or context exhaustion), in ascending slot order —
    ``outputs`` — a typed ``RequestOutput`` per live request, carrying
    *all* tokens emitted this step (speculative steps emit several) and
    the finish reason — and ``pj_per_token``, the decode-phase CIM energy
    per generated token (None when the arch serves without the CIM path).
    The energy is resolved lazily on first access (a thunk into
    ``Engine.energy_per_token``'s memo), so the decode hot path never
    pays the trace/ENOB solve for callers that don't read it. A finished
    slot is immediately claimable by ``add_request``."""

    def __init__(self, tokens: dict, finished: List[int],
                 energy_fn: Optional[callable] = None,
                 outputs: Optional[List[RequestOutput]] = None):
        super().__init__(tokens)
        self.finished = finished
        self.outputs: List[RequestOutput] = outputs if outputs is not None \
            else []
        self._energy_fn = energy_fn

    @property
    def pj_per_token(self) -> Optional[float]:
        return self._energy_fn() if self._energy_fn is not None else None


def _merge_cache(old, new, mask):
    """Per-lane cache merge: lanes where ``mask`` is True take the new
    cache. Attention caches are positionally overwritten anyway, but
    recurrent states (SSM/RG-LRU) mutate on every pass and MUST be frozen
    for lanes that did not really advance. Stacked super-block caches carry
    the batch on axis 1; tail caches on axis 0."""
    def mrg(axis):
        def f(o, n):
            shape = [1] * o.ndim
            shape[axis] = -1
            return jnp.where(jnp.reshape(mask, shape), n, o)
        return f

    out = {}
    if "superblocks" in old:
        out["superblocks"] = jax.tree.map(
            mrg(1), old["superblocks"], new["superblocks"])
    if "tail" in old:
        out["tail"] = jax.tree.map(mrg(0), old["tail"], new["tail"])
    return out


def _lane_keys(key, seeds, ctrs):
    """Per-lane sampling keys: unseeded lanes split the caller's per-step
    key (the legacy stream, bit-identical when no lane is seeded); a lane
    with ``seeds[i] >= 0`` instead derives ``fold_in(PRNGKey(seed), ctr)``
    from its own seed and per-lane sampling-event counter — a stream that
    is a pure function of (seed, event index), independent of slot
    placement, batch composition and the caller's key."""
    base = jax.random.split(key, seeds.shape[0])

    def pick(bk, seed, ctr):
        sk = jax.random.fold_in(jax.random.PRNGKey(jnp.maximum(seed, 0)),
                                ctr)
        return jnp.where(seed >= 0, sk, bk)

    return jax.vmap(pick)(base, seeds, ctrs)


def _decode_raw(arch: ArchConfig, sample: bool):
    """The unjitted fused decode-step body (forward + active-mask cache
    merge + token selection). Exposed separately from ``_decode_fn`` so the
    invariant harness (``repro.analysis.invariants``) can wrap it in a
    compile counter before jitting — same function, same trace.

    ``temp`` is a per-lane (B,) float32 vector (mixed greedy/sampled
    batches: a lane with ``temp <= 0`` takes the argmax even in the
    sampled executable); ``seeds``/``ctrs`` are the per-lane (B,) int32
    seed (-1 = unseeded) and sampling-event counter feeding
    ``_lane_keys``. With every lane unseeded and a uniform temperature
    this reproduces the legacy scalar-temperature stream bit-for-bit."""
    def fn(params, toks, cache, lengths, active, key, temp, seeds, ctrs):
        logits, new_cache = decode_step(params, toks, arch, cache, lengths)
        merged = _merge_cache(cache, new_cache, active)
        if sample:
            keys = _lane_keys(key, seeds, ctrs)
            nxt = jax.vmap(
                lambda k, lg, tt: jax.random.categorical(
                    k, lg / jnp.maximum(tt, 1e-6)))(keys, logits, temp)
            nxt = jnp.where(temp > 0, nxt, jnp.argmax(logits, axis=-1))
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), merged

    return fn


@functools.lru_cache(maxsize=64)
def _decode_fn(arch: ArchConfig, sample: bool):
    """One compiled decode executable per (arch, sampling mode), shared by
    every Engine.

    Sharing (rather than one ``jax.jit`` per Engine) keeps every engine for
    a given arch bitwise-consistent — XLA autotunes each compilation of the
    same HLO independently and a last-ulp logits difference flips greedy
    argmax near ties. The executable fuses the whole per-step hot path:
    decode forward, per-lane active-mask cache merge, and token selection
    (argmax, or per-lane temperature categorical when ``sample``), so
    logits and caches never cross the device boundary.
    """
    return jax.jit(_decode_raw(arch, sample))


def _prefill_raw(arch: ArchConfig, bucket: int):
    """The unjitted chunked-prefill body for one bucket length (see
    ``_decode_raw`` for why the raw/jit split exists)."""
    del bucket  # shapes carry the bucket; the key just partitions the cache
    return lambda p, t, c, i, l: prefill_step(p, t, arch, c, i, l)


@functools.lru_cache(maxsize=256)
def _prefill_fn(arch: ArchConfig, bucket: int):
    """One compiled chunked-prefill executable per (arch, bucket length),
    shared by every Engine. Buckets are powers of two (see
    ``Engine._bucket``), so the cache stays O(log max_ctx) per arch."""
    return jax.jit(_prefill_raw(arch, bucket))


def _verify_raw(arch: ArchConfig, bucket: int):
    """Sampled-acceptance speculative verify for one bucket length: a
    chunked prefill over ``[pending, d_1 .. d_{k-1}]`` plus the standard
    speculative rejection rule, entirely on device.

    Drafts here are *greedy* proposals (the draft model's argmax), i.e. a
    delta proposal distribution, so the textbook accept-with-prob
    ``min(1, p/q)`` reduces to: accept ``d_j`` with probability
    ``p(d_j)`` under the target distribution at that position; on the
    first rejection resample from ``p`` with the rejected token's mass
    zeroed out (the renormalized residual ``max(0, p - q)``), and when
    every draft survives sample the bonus token from the target's next
    distribution — unbiased w.r.t. sequential sampling (distribution-,
    not bit-, identical; see serving/speculative.py). Lanes with
    ``temp <= 0`` fall back to exact greedy acceptance, so mixed batches
    work. Returns a packed ``(B, S + 1)`` int32 array — emitted tokens
    (accepted drafts then the correction/bonus) followed by the per-lane
    emitted count — one fetch — plus the new cache.
    """
    del bucket  # shapes carry the bucket; the key just partitions the cache

    def fn(params, toks, cache, index, lens, key, temp, seeds, ctrs):
        b, s = toks.shape
        idx = jnp.broadcast_to(jnp.asarray(index), (b,))
        lens_b = jnp.broadcast_to(jnp.asarray(lens), (b,))
        positions = idx[:, None] + jnp.arange(s)[None, :]
        logits, _, new_cache = forward(
            params, toks, arch, cache=cache, cache_index=idx,
            positions=positions, chunk_lengths=lens_b)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, S)
        # position j verifies draft toks[:, j+1] (last column is junk and
        # masked off by draft_pos below)
        nxt = jnp.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        tt = jnp.maximum(temp, 1e-6)[:, None]                    # (B, 1)
        p = jax.nn.softmax(logits.astype(jnp.float32) / tt[..., None],
                           axis=-1)
        q = jnp.take_along_axis(p, nxt[..., None], axis=-1)[..., 0]
        keys = _lane_keys(key, seeds, ctrs)
        ks = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
        u = jax.vmap(lambda k: jax.random.uniform(k, (s,)))(ks[:, 0])
        acc = jnp.where((temp > 0)[:, None], u < q, nxt == greedy)
        jj = jnp.arange(s)[None, :]
        draft_pos = jj < (lens_b[:, None] - 1)
        run = jnp.cumprod(
            jnp.where(draft_pos, acc, True).astype(jnp.int32), axis=1)
        m = jnp.sum(run * draft_pos.astype(jnp.int32), axis=1)   # (B,)
        # final token at position m: correction (resample with the
        # rejected draft zeroed) or bonus (full target distribution)
        row = jnp.take_along_axis(logits, m[:, None, None], axis=1)[:, 0, :]
        row_greedy = jnp.argmax(row, axis=-1).astype(jnp.int32)
        rejected = m < (lens_b - 1)
        d_rej = jnp.take_along_axis(nxt, m[:, None], axis=1)[:, 0]
        row_f = row.astype(jnp.float32) / jnp.maximum(temp, 1e-6)[:, None]
        vocab = jnp.arange(row.shape[-1])[None, :]
        row_f = jnp.where(rejected[:, None] & (vocab == d_rej[:, None]),
                          jnp.asarray(-1e30, row_f.dtype), row_f)
        cand = jax.vmap(jax.random.categorical)(ks[:, 1],
                                                row_f).astype(jnp.int32)
        final = jnp.where(temp > 0, cand, row_greedy)
        emitted = jnp.where(jj < m[:, None], nxt, 0)
        emitted = jnp.where(jj == m[:, None], final[:, None], emitted)
        packed = jnp.concatenate(
            [emitted, (m + 1).astype(jnp.int32)[:, None]], axis=1)
        return packed, new_cache

    return fn


@functools.lru_cache(maxsize=256)
def _verify_fn(arch: ArchConfig, bucket: int):
    """One compiled sampled-verify executable per (arch, bucket length) —
    only ever compiled when speculative decode runs with sampling (the
    greedy acceptance path reuses ``_prefill_fn`` outright)."""
    return jax.jit(_verify_raw(arch, bucket))


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_ctx: int = 2048
    temperature: float = 0.0
    cache_dtype: str = "float32"
    # GR-MAC backend override for CIM-enabled archs (None keeps the arch's
    # CIMConfig.backend; see kernels.dispatch for the choices). Decode is a
    # small-M matmul, so "auto" plans onto the batched-einsum xla path;
    # cim_tile_m / cim_tile_n pin the tiled/Pallas tile sizes when set.
    cim_backend: Optional[str] = None
    cim_tile_m: Optional[int] = None
    cim_tile_n: Optional[int] = None
    # Default EOS token id: a lane emitting it is finished and its slot is
    # freed immediately (per-request override via add_request(eos_id=...)).
    # None decodes every lane to max_ctx (the legacy behavior).
    eos_id: Optional[int] = None
    # "bucketed": chunked prefill, prompts padded to power-of-two buckets
    # (the default); "token": legacy one-dispatch-per-token prefill, kept
    # as the equivalence oracle for tests/benchmarks
    prefill_mode: str = "bucketed"
    prefill_bucket_min: int = 8
    prefill_bucket_max: int = 1024
    # Prefix cache (repro.serving.prefix_cache): byte budget for cached
    # prefill snapshots. When set, ``begin_request`` adopts the longest
    # cached prefix into the claimed lane (only the suffix is dispatched)
    # and ``advance_prefill`` stores new chunk-aligned boundaries.
    # Requires bucketed prefill. None disables caching entirely.
    prefix_cache_bytes: Optional[int] = None


class Engine:
    def __init__(self, arch: ArchConfig, params, cfg: ServeConfig,
                 prefix_cache: Optional["PrefixCache"] = None):
        assert arch.input_mode == "tokens", "engine serves token models"
        if cfg.cim_backend is not None:
            arch = arch.replace(cim=arch.cim.with_backend(cfg.cim_backend))
        if cfg.cim_tile_m is not None or cfg.cim_tile_n is not None:
            arch = arch.replace(cim=arch.cim.with_tiles(
                cfg.cim_tile_m, cfg.cim_tile_n))
        self.arch = arch
        self.cfg = cfg
        self.params = params
        self.cache = init_cache(
            arch, cfg.batch_slots, cfg.max_ctx, jnp.dtype(cfg.cache_dtype))
        self.lengths = np.zeros(cfg.batch_slots, np.int32)
        self.active = np.zeros(cfg.batch_slots, bool)
        self.tokens: List[List[int]] = [[] for _ in range(cfg.batch_slots)]
        # last emitted token per lane, fed back as next decode input
        self._last_host = np.zeros(cfg.batch_slots, np.int32)
        # per-slot EOS id (-1: none); seeded from cfg.eos_id per request
        self._eos = np.full(cfg.batch_slots, -1, np.int64)
        # per-slot SamplingParams state (seeded at begin_request):
        # temperature, PRNG seed (-1 unseeded) + sampling-event counter,
        # generated-token cap (-1 unlimited) + emitted count, spec_k
        # (-1: decoder default), and the terminal finish reason
        self._temp = np.full(cfg.batch_slots, cfg.temperature, np.float32)
        self._seed = np.full(cfg.batch_slots, -1, np.int64)
        self._ctr = np.zeros(cfg.batch_slots, np.int64)
        self._max_toks = np.full(cfg.batch_slots, -1, np.int64)
        self._emitted = np.zeros(cfg.batch_slots, np.int64)
        self._spec_k = np.full(cfg.batch_slots, -1, np.int64)
        self._finish_reason: List[Optional[str]] = \
            [None] * cfg.batch_slots
        # slots that have hosted a request (their cache state is dirty and
        # must be zeroed before reuse)
        self._dirty = np.zeros(cfg.batch_slots, bool)
        # slots claimed by a request whose prefill has not finished yet:
        # reserved (not claimable) but not in the decode batch either
        self._prefilling = np.zeros(cfg.batch_slots, bool)
        # per-slot prompt tokens not yet prefilled / last chunk's logits
        self._pending_prompt: Dict[int, List[int]] = {}
        self._pending_logits: Dict[int, jax.Array] = {}
        # slots completed outside step() (first prefill token == EOS),
        # surfaced through the next StepResult.finished
        self._pending_finished: List[int] = []
        # lazily-computed decode-phase energy report (None until asked)
        self._energy: Optional[dict] = None
        # Prefix cache: an explicit instance may be shared across engines
        # (cache-aware routing); cfg.prefix_cache_bytes builds a private
        # one. Chunk granularity MUST be the smallest prefill bucket so
        # every stored boundary composes with the existing power-of-two
        # bucket executables — zero new compiles on the hit path.
        if prefix_cache is None and cfg.prefix_cache_bytes is not None:
            prefix_cache = PrefixCache(cfg.prefix_cache_bytes,
                                       chunk_tokens=cfg.prefill_bucket_min)
        if prefix_cache is not None:
            if cfg.prefill_mode != "bucketed":
                raise ValueError(
                    "prefix cache requires prefill_mode='bucketed' (the "
                    "token path replays whole prompts)")
            if prefix_cache.chunk != cfg.prefill_bucket_min:
                raise ValueError(
                    f"prefix cache chunk {prefix_cache.chunk} != "
                    f"prefill_bucket_min {cfg.prefill_bucket_min}: cached "
                    "boundaries would not align with bucket executables")
        self.prefix_cache = prefix_cache
        # tokens adopted from the prefix cache for the slot's current
        # occupant (0 = cold prefill) — the scheduler's savings counter
        self._adopted = np.zeros(cfg.batch_slots, np.int64)
        # prefill_tokens counts prompt tokens actually dispatched (suffix
        # only, under hits) — the CostLedger's prefill energy multiplier
        self.stats = {"prefill_dispatches": 0, "decode_steps": 0,
                      "prefill_tokens": 0, "prefix_hit_tokens": 0,
                      # speculative-decode counters (speculative.py):
                      # dispatches by kind, iterations, and tokens
                      # emitted through spec steps (accepted incl. the
                      # correction/bonus token)
                      "draft_dispatches": 0, "verify_dispatches": 0,
                      "repair_dispatches": 0, "spec_steps": 0,
                      "spec_tokens": 0}

    # ------------------------------------------------------- compiled fns
    # Per-engine indirection over the shared executable caches: the single
    # seam through which every compiled dispatch flows, so the invariant
    # harness (repro.analysis.invariants) can interpose counters without
    # touching the hot-path call sites.
    def _compiled_decode(self, sample: bool):
        return _decode_fn(self.arch, sample)

    def _compiled_prefill(self, bucket: int):
        return _prefill_fn(self.arch, bucket)

    def _compiled_draft(self, draft_arch: ArchConfig):
        # the draft is a plain greedy decode of the (cheap) draft arch
        # over the SAME weights and cache — when draft_arch == self.arch
        # this is literally the serving decode executable (zero new
        # compiles); otherwise it is the draft arch's one decode compile
        return _decode_fn(draft_arch, False)

    def _compiled_verify(self, bucket: int):
        return _verify_fn(self.arch, bucket)

    @staticmethod
    def _snapshot(host_state: np.ndarray) -> jax.Array:
        """Immutable device view of mutable per-slot host state.

        ``jnp.asarray(numpy_array)`` is zero-copy on CPU when the buffer is
        aligned, so the jax Array *aliases* ``self.lengths``/``self.active``.
        The engine mutates those in place right after dispatching the decode
        — which executes asynchronously — so without a defensive copy the
        computation can read the post-increment value and write the KV cache
        at the wrong slot position (rare, load-dependent token corruption).
        """
        return jnp.asarray(host_state.copy())

    # ------------------------------------------------------------ params
    @staticmethod
    def _resolve_params(eos_id: Optional[int],
                        params: Optional[SamplingParams],
                        stacklevel: int = 4) -> SamplingParams:
        """Fold the legacy ``eos_id=`` kwarg into ``SamplingParams`` (one
        release of ``DeprecationWarning``; passing both is an error).
        ``params=None`` with no legacy kwargs is the silent default."""
        if params is None:
            if eos_id is not None:
                warnings.warn(
                    "eos_id= is deprecated: pass "
                    "params=SamplingParams(eos_id=...) instead (the "
                    "behavior is identical)", DeprecationWarning,
                    stacklevel=stacklevel)
            return SamplingParams(eos_id=eos_id)
        if eos_id is not None:
            raise ValueError(
                "pass eos_id via SamplingParams, not alongside params=")
        return params

    # ------------------------------------------------------------ prefill
    def add_request(self, prompt: List[int],
                    eos_id: Optional[int] = None,
                    key: Optional[jax.Array] = None, *,
                    params: Optional[SamplingParams] = None) -> int:
        """Prefill a free slot, sample the first output token from the
        prefill logits, and return the slot id.

        Bucketed mode splits the prompt into ``prefill_bucket_max``-sized
        chunks, pads the remainder up to a power of two, and issues one
        compiled dispatch per chunk — ``ceil(len / bucket_max)`` dispatches
        (never more than ``ceil(log2(len)) + 1`` for prompts that fit the
        context), vs ``len`` in legacy ``prefill_mode="token"``.

        Both modes adopt the last-valid-token logits to produce the first
        generated token here (appended to ``tokens[slot]``), so the first
        ``step`` feeds *that* token — no decode dispatch ever re-feeds the
        last prompt token, whose K/V used to be written twice (at len-1
        and len). Pass ``key`` to sample it when ``temperature > 0``
        (greedy argmax otherwise, exactly like ``step``). A first token
        that hits the request's EOS finishes the request immediately (the
        slot never joins the decode batch and is free to reuse).

        ``params`` (``SamplingParams``) is the request-level entry point:
        temperature / seed / eos_id / max_tokens / spec_k, each ``None``
        field inheriting the engine default. The positional ``eos_id``
        kwarg is the deprecated legacy spelling (one release of
        ``DeprecationWarning``; identical behavior).

        Sampling: with ``temperature > 0`` the first token is sampled
        **only when** ``key`` is passed; ``temperature > 0`` without a
        key falls back to greedy argmax with a ``UserWarning`` (the
        explicit form of what used to happen silently — ``step`` applies
        the same key-gated rule). A request with ``params.seed`` set
        samples from its own seeded stream, no per-step key needed.
        """
        params = self._resolve_params(eos_id, params)
        slot = self.begin_request(prompt, params=params)
        if self.cfg.prefill_mode == "token":
            sample = self._resolve_sampling(key, slot)
            self._pending_prompt.pop(slot, None)
            for t in prompt[:-1]:
                self._advance_slot(slot, t)
            # the final dispatch's ids ARE the last-valid-token selection
            first = self._advance_slot(slot, prompt[-1], sample=sample,
                                       key=key)
            self._adopt_first_token(slot, first)
        else:
            while self.prefill_remaining(slot):
                self.advance_prefill(slot)
            self.finish_prefill(slot, key=key)
        return slot

    # ------------------------------------------------- incremental prefill
    def begin_request(self, prompt: List[int],
                      eos_id: Optional[int] = None, *,
                      params: Optional[SamplingParams] = None) -> int:
        """Claim and validate a free slot for ``prompt`` without running
        any prefill: the lane is *reserved* (``free_slots`` excludes it)
        but not yet in the decode batch. The scheduler drains the prompt
        through ``advance_prefill`` between decode steps and activates the
        lane with ``finish_prefill``; ``add_request`` is the blocking
        begin → advance-until-drained → finish composition of the same
        methods. ``params`` seeds the lane's per-request state (see
        ``add_request``); the ``eos_id`` kwarg is the deprecated legacy
        spelling."""
        params = self._resolve_params(eos_id, params)
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.cfg.max_ctx:
            # strictly less: the first decode step writes the first
            # *generated* token's K/V at position len(prompt), which must
            # still be a valid cache index (at len == max_ctx it would
            # clamp onto the last prompt entry and corrupt the lane)
            raise ValueError(
                f"prompt of {len(prompt)} tokens needs max_ctx > "
                f"{len(prompt)} (got {self.cfg.max_ctx}) to decode")
        free = np.where(~self.active & ~self._prefilling)[0]
        if len(free) == 0:
            raise RuntimeError("no free slots")
        slot = int(free[0])
        if self._dirty[slot]:
            self._reset_slot_state(slot)
        self._dirty[slot] = True
        self.tokens[slot] = list(prompt)
        self.lengths[slot] = 0
        self._prefilling[slot] = True
        self._pending_prompt[slot] = list(prompt)
        self._pending_logits.pop(slot, None)
        eos = params.eos_id if params.eos_id is not None else self.cfg.eos_id
        self._eos[slot] = -1 if eos is None else int(eos)
        self._temp[slot] = (self.cfg.temperature
                            if params.temperature is None
                            else params.temperature)
        self._seed[slot] = -1 if params.seed is None else int(params.seed)
        self._ctr[slot] = 0
        self._max_toks[slot] = (-1 if params.max_tokens is None
                                else int(params.max_tokens))
        self._emitted[slot] = 0
        self._spec_k[slot] = (-1 if params.spec_k is None
                              else int(params.spec_k))
        self._finish_reason[slot] = None
        self._adopted[slot] = 0
        if self.prefix_cache is not None:
            hit = self.prefix_cache.lookup(prompt)
            if hit is not None:
                # adopt the cached prefix into the (just-zeroed) lane:
                # device-side restore, then prefill only the suffix from
                # cache index P — same bucket executables, no new compiles
                p, snap = hit
                self.cache = restore_slot(self.cache, slot, p, snap)
                self.lengths[slot] = p
                self._pending_prompt[slot] = list(prompt[p:])
                self._adopted[slot] = p
                self.stats["prefix_hit_tokens"] += p
        return slot

    def prefill_remaining(self, slot: int) -> int:
        """Prompt tokens of ``slot`` not yet prefilled (0 once drained)."""
        return len(self._pending_prompt.get(slot, ()))

    def adopted_prefix(self, slot: int) -> int:
        """Prompt tokens the slot's current occupant adopted from the
        prefix cache at ``begin_request`` (0 = cold prefill). The
        scheduler reads this right after admission for its
        prefill-tokens-saved / recompute-savings accounting."""
        return int(self._adopted[slot])

    def advance_prefill(self, slot: int,
                        max_tokens: Optional[int] = None) -> int:
        """One bucketed chunk dispatch for a mid-prefill slot: consumes
        ``min(remaining, prefill_bucket_max, max_tokens)`` prompt tokens
        through the shared power-of-two bucket executables (a budget-
        truncated chunk pads up to the next bucket, so interleaving never
        compiles anything the blocking path would not). Returns the number
        of tokens consumed; the chunk's last-valid-token logits are kept
        on device for ``finish_prefill``."""
        rem = self._pending_prompt[slot]
        take = min(len(rem), self.cfg.prefill_bucket_max)
        if max_tokens is not None:
            take = min(take, int(max_tokens))
        if take <= 0:
            return 0
        pc = self.prefix_cache
        if pc is not None and take < len(rem):
            # a further chunk follows anyway: shrink this one so it ends
            # on a cache-chunk boundary (snapshots exist only there). The
            # truncated chunk pads to a smaller-or-equal power-of-two
            # bucket, so no new executable is introduced.
            aligned = take - (int(self.lengths[slot]) + take) % pc.chunk
            if aligned >= 1:
                take = aligned
        self._pending_logits[slot] = self._prefill_chunk(slot, rem[:take])
        del rem[:take]
        if pc is not None:
            done = int(self.lengths[slot])
            if done > 0 and done % pc.chunk == 0:
                # capture the boundary live (recurrent state at an
                # interior length is unrecoverable later); insert() skips
                # the snapshot thunk when the boundary is already stored
                pc.insert(self.tokens[slot][:done],
                          lambda: snapshot_slot(self.cache, slot, done))
        return take

    def finish_prefill(self, slot: int,
                       key: Optional[jax.Array] = None) -> int:
        """Select the first output token from the final chunk's logits and
        activate the lane for decode (or finish it immediately when that
        token is the request's EOS — see ``add_request``). Requires the
        prompt fully drained. Applies the documented sampling contract:
        ``temperature > 0`` without ``key`` warns and falls back to greedy
        argmax."""
        if self.prefill_remaining(slot):
            raise RuntimeError(
                f"slot {slot}: {self.prefill_remaining(slot)} prompt "
                "tokens still pending — drain with advance_prefill first")
        sample = self._resolve_sampling(key, slot)
        logits = self._pending_logits.pop(slot)
        del self._pending_prompt[slot]
        first = self._select_token(logits, slot, sample, key)
        self._adopt_first_token(slot, first)
        return first

    def _adopt_first_token(self, slot: int, first: int) -> None:
        """Shared end-of-prefill bookkeeping: record the first generated
        token and either join the decode batch or finish at once (first
        token == EOS, or ``max_tokens == 1``: the slot never joins a
        decode batch, so the completion is surfaced through the next
        ``StepResult.finished``)."""
        self.tokens[slot].append(first)
        self._last_host[slot] = first
        self._prefilling[slot] = False
        self._emitted[slot] = 1
        if self._eos[slot] >= 0 and first == self._eos[slot]:
            self.active[slot] = False
            self._finish_reason[slot] = "eos"
            self._pending_finished.append(slot)
        elif 0 <= self._max_toks[slot] <= 1:
            self.active[slot] = False
            self._finish_reason[slot] = "length"
            self._pending_finished.append(slot)
        else:
            self.active[slot] = True

    def finish_reason(self, slot: int) -> Optional[str]:
        """Terminal reason recorded when the engine froze the lane
        (``"eos"`` / ``"length"`` / ``"ctx"``); None while live or when
        the slot was freed externally (``release_slot``)."""
        return self._finish_reason[slot]

    def release_slot(self, slot: int) -> None:
        """Free a lane regardless of progress — the scheduler's stop seam
        (request hit its ``max_new_tokens``; preemption under overload).
        Mid-prefill state is discarded; the dirty flag stays set so the
        next claim zeroes the lane's recurrent cache state."""
        self.active[slot] = False
        self._prefilling[slot] = False
        self._pending_prompt.pop(slot, None)
        self._pending_logits.pop(slot, None)

    def free_slots(self) -> int:
        """Slots claimable by ``begin_request``/``add_request`` right now
        (neither decoding nor mid-prefill) — the admission-control count."""
        return int(np.sum(~self.active & ~self._prefilling))

    def _resolve_sampling(self, key: Optional[jax.Array],
                          slot: int) -> bool:
        """The per-request sampling rule: sample iff the slot's
        temperature is ``> 0`` AND entropy is available — a per-call key,
        or the request's own ``SamplingParams.seed``. The no-key no-seed
        fallback to greedy is explicit: it warns instead of silently
        diverging from what a ``temperature > 0`` caller expects."""
        if self._temp[slot] <= 0:
            return False
        if self._seed[slot] >= 0:
            return True
        if key is None:
            warnings.warn(
                "temperature > 0 but no PRNG key passed: falling back to "
                "greedy argmax for this token. Pass key= to sample "
                "(Engine.step applies the same key-gated rule), or set "
                "SamplingParams.seed for a self-seeded request.",
                UserWarning, stacklevel=3)
            return False
        return True

    def _effective_temps(self, key: Optional[jax.Array]) -> np.ndarray:
        """Per-lane temperatures actually in effect for one sampling
        event: a lane samples iff its temperature is positive AND it has
        entropy (the caller's key, or its own seed) — lanes without are
        clamped to 0 (argmax) inside the sampled executable."""
        if key is not None:
            return self._temp.astype(np.float32)
        return np.where(self._seed >= 0, self._temp, 0.0).astype(np.float32)

    def _sampling_args(self, key: Optional[jax.Array],
                       eff: np.ndarray) -> tuple:
        """The (key, temp, seeds, ctrs) tail of every sampled executable
        call, snapshotted against async mutation like ``_snapshot``."""
        return (key if key is not None else jax.random.PRNGKey(0),
                jnp.asarray(eff.copy()),
                jnp.asarray(self._seed.astype(np.int32)),
                jnp.asarray(self._ctr.astype(np.int32)))

    def _count_sampling_event(self, eff: np.ndarray,
                              lanes: np.ndarray) -> None:
        """Advance the sampling-event counter of every seeded lane that
        just consumed randomness (its stream is ``fold_in(seed, ctr)``
        per event, so placement and co-traffic can never perturb it)."""
        self._ctr[lanes & (eff > 0) & (self._seed >= 0)] += 1

    def _select_token(self, logits_dev: jax.Array, slot: int,
                      sample: bool, key: Optional[jax.Array]) -> int:
        """Token selection over prefill logits (B, V), mirroring the fused
        decode's math exactly (per-lane keys + categorical / argmax with
        per-lane temperatures) so token-mode and bucketed-mode prefill
        stay equivalent. Routed through ``_fetch`` — the engine's single
        transfer point."""
        if sample:
            eff = self._effective_temps(key)
            k, temps, seeds, ctrs = self._sampling_args(key, eff)
            keys = _lane_keys(k, seeds, ctrs)
            ids = jax.vmap(
                lambda kk, lg, tt: jax.random.categorical(
                    kk, lg / jnp.maximum(tt, 1e-6)))(keys, logits_dev,
                                                     temps)
            ids = jnp.where(jnp.asarray(eff) > 0, ids,
                            jnp.argmax(logits_dev, axis=-1))
            lane = np.zeros(self.cfg.batch_slots, bool)
            lane[slot] = True
            self._count_sampling_event(eff, lane)
        else:
            ids = jnp.argmax(logits_dev, axis=-1)
        return int(self._fetch(ids.astype(jnp.int32))[slot])

    def _reset_slot_state(self, slot: int):
        """Zero one lane's cache before a freed slot hosts a new request.

        Attention KV is positionally overwritten and length-masked, so it
        cannot leak — but RG-LRU/SSM recurrent states persist across the
        request boundary and would seed the new prompt's prefill scan with
        the previous occupant's state."""
        def z(axis):
            def f(a):
                idx = [slice(None)] * a.ndim
                idx[axis] = slot
                return a.at[tuple(idx)].set(0)
            return f
        out = dict(self.cache)
        if "superblocks" in out:
            out["superblocks"] = jax.tree.map(z(1), out["superblocks"])
        if "tail" in out:
            out["tail"] = jax.tree.map(z(0), out["tail"])
        self.cache = out

    def _bucket(self, n: int) -> int:
        b = self.cfg.prefill_bucket_min
        while b < n:
            b *= 2
        return b

    def _prefill_chunk(self, slot: int, chunk: List[int]) -> jax.Array:
        """One bucketed prefill dispatch: the chunk is right-padded to its
        bucket and every other lane rides along frozen (length 0), so the
        returned cache is adopted wholesale — no merge. Returns the
        last-valid-token logits (B, V) on device (the final chunk's feed
        the first-output-token selection)."""
        bucket = self._bucket(len(chunk))
        toks = np.zeros((self.cfg.batch_slots, bucket), np.int32)
        toks[slot, :len(chunk)] = chunk
        lens = np.zeros(self.cfg.batch_slots, np.int32)
        lens[slot] = len(chunk)
        fill = self._compiled_prefill(bucket)
        logits, _, self.cache = fill(
            self.params, jnp.asarray(toks), self.cache,
            self._snapshot(self.lengths), jnp.asarray(lens))
        self.lengths[slot] += len(chunk)
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += len(chunk)
        return logits

    def _advance_slot(self, slot: int, token: int, sample: bool = False,
                      key: Optional[jax.Array] = None) -> int:
        # Legacy token-by-token prefill: a batched decode call with per-slot
        # indices, all lanes but ``slot`` masked out of the cache merge.
        # Returns this slot's selected next token (meaningful on the final
        # prompt token, where it is the first generated token).
        toks = np.zeros((self.cfg.batch_slots, 1), np.int32)
        toks[slot, 0] = token
        mask = np.zeros(self.cfg.batch_slots, bool)
        mask[slot] = True
        eff = self._effective_temps(key) if sample else \
            np.zeros(self.cfg.batch_slots, np.float32)
        ids, self.cache = self._compiled_decode(sample)(
            self.params, jnp.asarray(toks), self.cache,
            self._snapshot(self.lengths), jnp.asarray(mask),
            *self._sampling_args(key, eff))
        if sample:
            self._count_sampling_event(eff, mask)
        self.lengths[slot] += 1
        self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += 1
        return int(self._fetch(ids)[slot])

    # ------------------------------------------------------------ decode
    def step(self, key: Optional[jax.Array] = None) -> "StepResult":
        """One decode step for every active slot.

        The compiled decode returns only the sampled token ids; everything
        else (logits, cache merge, sampling) stays on device. Pass ``key``
        (and set ``temperature > 0``) for per-lane categorical sampling;
        greedy argmax otherwise.

        Returns a ``StepResult`` (a dict of slot id -> token, exactly as
        before) whose ``finished`` attribute lists the slots freed this
        step — lanes that emitted their EOS or ran out of context — and
        whose ``pj_per_token`` carries the decode-phase CIM energy per
        generated token (ledger-derived, see ``energy_per_token``; None
        when the arch serves without the CIM path). Freed slots drop out
        of the active mask (their caches freeze inside the fused decode)
        and are immediately claimable by ``add_request``. Requests that
        completed during ``add_request`` itself (first prefill-sampled
        token == EOS) are reported here too, ahead of this step's frees.
        """
        pending, outputs = self._drain_pending()
        if not self.active.any():
            return StepResult({}, pending, self._pj_per_token,
                              outputs=outputs)
        eff = self._effective_temps(key)
        sample = bool((eff[self.active] > 0).any())
        fn = self._compiled_decode(sample)
        ids_dev, self.cache = fn(
            self.params, self._snapshot(self._last_host[:, None]),
            self.cache, self._snapshot(self.lengths),
            self._snapshot(self.active),
            *self._sampling_args(key, eff))
        if sample:
            self._count_sampling_event(eff, self.active)
        ids = self._fetch(ids_dev)
        act = np.where(self.active)[0]
        out = {}
        for s in act:
            t = int(ids[s])
            self.tokens[s].append(t)
            out[int(s)] = t
        self._last_host[act] = ids[act]
        self.lengths[act] += 1
        self._emitted[act] += 1
        # Per-slot completion: emitted EOS, hit the request's max_tokens,
        # or no context left for another decode write. Either way the slot
        # leaves the active mask (its cache freezes in the next fused
        # decode) and is free to reuse.
        hit_eos = (self._eos >= 0) & (self._last_host == self._eos)
        maxed = (self._max_toks >= 0) & (self._emitted >= self._max_toks)
        done = self.active & (hit_eos | maxed
                              | (self.lengths >= self.cfg.max_ctx))
        for s in act:
            reason = None
            if done[s]:
                reason = ("eos" if hit_eos[s]
                          else "length" if maxed[s] else "ctx")
                self._finish_reason[s] = reason
            outputs.append(RequestOutput(
                slot=int(s), tokens=[out[int(s)]], finished=bool(done[s]),
                finish_reason=reason, _energy_fn=self._pj_per_token))
        finished = pending + [int(s) for s in np.where(done)[0]]
        self.active[done] = False
        self.stats["decode_steps"] += 1
        return StepResult(out, finished, self._pj_per_token,
                          outputs=outputs)

    def _drain_pending(self):
        """Pop completions recorded outside ``step`` (first prefill token
        hit EOS / a one-token ``max_tokens`` cap) as (slot ids, their
        token-less ``RequestOutput`` records)."""
        pending, self._pending_finished = self._pending_finished, []
        outputs = [RequestOutput(slot=s, tokens=[], finished=True,
                                 finish_reason=self._finish_reason[s],
                                 _energy_fn=self._pj_per_token)
                   for s in pending]
        return pending, outputs

    # ------------------------------------------------- speculative seams
    # Orchestrated by repro.serving.speculative.SpecDecoder; kept on the
    # engine because they touch cache/state internals and must flow
    # through the instrumented _compiled_*/_fetch seams.

    _SPEC_STATE_KINDS = ("local", "rglru", "ssm")

    def spec_snapshot(self) -> dict:
        """References to the rollback-sensitive cache subtrees, whole
        batch: local-attn ring buffers (drafting overwrites ring slots
        that alias *valid older* positions) and RG-LRU/SSM recurrent +
        conv states (mutated by every pass). jax arrays are immutable and
        dispatches REPLACE ``self.cache`` leaves, so this is O(1)
        bookkeeping — no copy, no transfer. Global-attn KV needs no
        snapshot: rows past a lane's committed length are causally masked
        (decode: ``slot <= idx``; prefill: ``q_pos >= k_pos``) and are
        positionally overwritten before the length ever reaches them, so
        draft/verify pollution there is invisible by construction. An
        empty dict therefore means the arch is rollback-free."""
        snap = {}
        for group in ("superblocks", "tail"):
            g = self.cache.get(group)
            if not g:
                continue
            kept = {name: layer for name, layer in g.items()
                    if name.split("_", 1)[1] in self._SPEC_STATE_KINDS}
            if kept:
                snap[group] = kept
        return snap

    def spec_restore(self, snap: dict, lanes: np.ndarray) -> None:
        """Restore a ``spec_snapshot`` into the lanes where ``lanes`` is
        True (device-side per-lane where-merge, same layout rules as
        ``_merge_cache``); other lanes keep their current state. Free of
        device→host traffic — the merge rides into the next dispatch."""
        if not snap:
            return
        mask = jnp.asarray(lanes.copy())

        def mrg(axis):
            def f(cur, old):
                shape = [1] * cur.ndim
                shape[axis] = -1
                return jnp.where(jnp.reshape(mask, shape), old, cur)
            return f

        out = dict(self.cache)
        for group, axis in (("superblocks", 1), ("tail", 0)):
            if group not in snap:
                continue
            newg = dict(out[group])
            for name, layer in snap[group].items():
                newg[name] = jax.tree.map(mrg(axis), newg[name], layer)
            out[group] = newg
        self.cache = out

    def draft_step(self, draft_arch: ArchConfig, cur: np.ndarray,
                   mask: np.ndarray, offsets: np.ndarray) -> np.ndarray:
        """One greedy draft decode dispatch of ``draft_arch`` (same
        weights, shared cache) for the lanes in ``mask``, feeding token
        ``cur[i]`` at cache offset ``offsets[i]``. Non-mask lanes are
        frozen by the in-executable merge exactly like inactive decode
        lanes. Returns the drafted ids (one fetch)."""
        toks = np.zeros((self.cfg.batch_slots, 1), np.int32)
        toks[mask, 0] = cur[mask]
        zero = np.zeros(self.cfg.batch_slots, np.float32)
        ids_dev, self.cache = self._compiled_draft(draft_arch)(
            self.params, jnp.asarray(toks), self.cache,
            self._snapshot(offsets.astype(np.int32)),
            jnp.asarray(mask.copy()), *self._sampling_args(None, zero))
        self.stats["draft_dispatches"] += 1
        return self._fetch(ids_dev)

    def verify_chunk(self, chunk: np.ndarray,
                     lens: np.ndarray) -> np.ndarray:
        """Exact greedy verification of ``chunk`` — per lane
        ``[pending_token, d_1 .. d_{k-1}]`` with ``lens`` valid entries
        (0 freezes the lane bitwise) — through the *existing* bucketed
        prefill executable: ``prefill_step`` already returns per-position
        argmax ids, so the verifier costs zero new compiles. Returns the
        (B, bucket) target ids (one fetch); the host keeps the longest
        draft prefix matching them. Lengths are NOT committed here — the
        orchestrator commits only accepted tokens."""
        b, k = chunk.shape
        bucket = self._bucket(k)
        toks = np.zeros((b, bucket), np.int32)
        toks[:, :k] = chunk
        _, ids_dev, self.cache = self._compiled_prefill(bucket)(
            self.params, jnp.asarray(toks), self.cache,
            self._snapshot(self.lengths),
            jnp.asarray(lens.astype(np.int32).copy()))
        self.stats["verify_dispatches"] += 1
        return self._fetch(ids_dev)[:, :k]

    def verify_chunk_sampled(self, chunk: np.ndarray, lens: np.ndarray,
                             key: Optional[jax.Array]):
        """Rejection-rule verification of ``chunk`` under per-lane
        temperatures (``_verify_raw`` carries the acceptance math and the
        unbiasedness argument). Returns ``(emitted, counts)`` — emitted
        tokens (B, bucket) with ``counts[i]`` valid entries per lane —
        from the packed single fetch. Lanes with ``temp <= 0`` get exact
        greedy acceptance, so mixed batches verify in one dispatch."""
        b, k = chunk.shape
        bucket = self._bucket(k)
        toks = np.zeros((b, bucket), np.int32)
        toks[:, :k] = chunk
        eff = self._effective_temps(key)
        packed_dev, self.cache = self._compiled_verify(bucket)(
            self.params, jnp.asarray(toks), self.cache,
            self._snapshot(self.lengths),
            jnp.asarray(lens.astype(np.int32).copy()),
            *self._sampling_args(key, eff))
        self._count_sampling_event(eff, lens > 0)
        self.stats["verify_dispatches"] += 1
        packed = self._fetch(packed_dev)
        return packed[:, :k], packed[:, bucket]

    def repair_chunk(self, chunk: np.ndarray, lens: np.ndarray,
                     index: np.ndarray) -> None:
        """Partial-acceptance repair: after ``spec_restore`` rolled the
        rollback-sensitive state of partially-accepted lanes back to the
        pre-draft snapshot, re-feed each such lane's *accepted* prefix
        (``lens[i]`` leading tokens of ``chunk``, 0 = frozen) at its
        pre-verify offset ``index``. Same bucket executable as the
        verify; logits and ids are discarded on device — acceptance
        already knows every token, so repair adds NO fetch (the invariant
        ``run_spec_invariants`` checks)."""
        b, k = chunk.shape
        bucket = self._bucket(k)
        toks = np.zeros((b, bucket), np.int32)
        toks[:, :k] = chunk
        _, _, self.cache = self._compiled_prefill(bucket)(
            self.params, jnp.asarray(toks), self.cache,
            self._snapshot(index.astype(np.int32)),
            jnp.asarray(lens.astype(np.int32).copy()))
        self.stats["repair_dispatches"] += 1

    # ------------------------------------------------------------ energy
    def energy_per_token(self) -> Optional[dict]:
        """Decode-phase energy report for this engine's served arch: the
        ``core.costs`` ledger of one decode step priced per site, per
        generated token. Computed lazily once per engine (a shape-only
        trace + the memoized ENOB solve); None when the arch's CIM path
        is off."""
        if not self.arch.cim.enabled:
            return None
        if self._energy is None:
            self._energy = costs.price_ledger(
                costs.trace_decode(self.arch), 1)
            self.stats["pj_per_token"] = self._energy["pj_per_token"]
        return self._energy

    def _pj_per_token(self) -> Optional[float]:
        rep = self.energy_per_token()
        return None if rep is None else rep["pj_per_token"]

    @staticmethod
    def _fetch(ids_dev: jax.Array) -> np.ndarray:
        """The single device→host transfer per compiled dispatch that
        needs one: a (batch_slots,) int32 id array per decode/draft step
        and prefill first-token selection, or a (batch_slots, bucket[+1])
        int32 array per speculative verify. Repair dispatches cross
        nothing."""
        return np.asarray(ids_dev)


def energy_report(arch: ArchConfig, *, batch: int = 1,
                  prefill_bucket: int = 128,
                  train_seq: Optional[int] = None,
                  seed: int = 0, n_cols: int = 1 << 11) -> dict:
    """Ledger-derived CIM energy report (pJ/token) for all three phases.

    Traces the *real* model functions — ``prefill_step`` (one
    ``prefill_bucket``-token dispatch), the decode step, and the train
    step — into ``core.costs.CostLedger``s and prices every recorded
    contract at its site's resolved design (``CIMConfig.for_site``), so
    mixed per-site deployments (``site_overrides``) and per-phase shape
    differences are priced faithfully and the numbers can never drift
    from the model code. Top-level keys alias the decode phase (the
    deployment metric the paper optimizes); ``phases`` carries the full
    per-phase, per-site breakdown. ``seed``/``n_cols`` configure the
    underlying Monte-Carlo ENOB solve (both participate in its
    memoization key).
    """
    if not arch.cim.enabled:
        return {"enabled": False}
    phases = costs.phase_report(arch, batch=batch,
                                prefill_bucket=prefill_bucket,
                                train_seq=train_seq, seed=seed,
                                n_cols=n_cols)
    dec = phases["decode"]
    return {
        "enabled": True,
        "phases": phases,
        # decode-phase aliases: the legacy per-decoded-token metric
        "fj_per_op": dec["fj_per_op"],
        "conventional_fj_per_op": dec["conventional_fj_per_op"],
        "ops_per_token": dec["ops_per_token"],
        "analog_ops_per_token": dec["analog_ops_per_token"],
        "pj_per_token": dec["pj_per_token"],
        "sites": dec["sites"],
    }
