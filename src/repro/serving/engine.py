"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch, with prefill, per-slot lengths, and greedy/temperature
sampling. The decode step is a single jit'd function over the whole batch
(caches included), so the engine maps directly onto the sharded serve_step
that the multi-pod dry-run lowers.

Per-token CIM energy accounting: when the arch config has the GR-CIM path
enabled, ``energy_report`` walks the model dims and prices every projection
matmul with the paper's cost model (fJ/Op) — the deployment metric the
paper optimizes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.dse import evaluate_point
from repro.models import decode_step, forward, init_cache

__all__ = ["ServeConfig", "Engine", "energy_report"]


@functools.lru_cache(maxsize=32)
def _decode_fn(arch: ArchConfig):
    """One compiled decode executable per arch, shared by every Engine.

    Compiling the identical decode HLO once per Engine instance (a fresh
    ``jax.jit(lambda ...)`` each time) lets XLA autotune each copy
    independently; on CPU that can pick different reduction strategies for
    different compilations of the *same* program, and a last-ulp logits
    difference flips greedy argmax near ties. Sharing the executable makes
    every engine for a given arch bitwise-consistent (and drops the
    per-engine compile cost).
    """
    return jax.jit(lambda p, t, c, i: decode_step(p, t, arch, c, i))


def _merge_cache(old, new, mask):
    """Per-lane cache merge: lanes where ``mask`` is True take the new
    cache. Attention caches are positionally overwritten anyway, but
    recurrent states (SSM/RG-LRU) mutate on every pass and MUST be frozen
    for lanes that did not really advance. Stacked super-block caches carry
    the batch on axis 1; tail caches on axis 0."""
    def mrg(axis):
        def f(o, n):
            shape = [1] * o.ndim
            shape[axis] = -1
            return jnp.where(jnp.reshape(mask, shape), n, o)
        return f

    out = {}
    if "superblocks" in old:
        out["superblocks"] = jax.tree.map(
            mrg(1), old["superblocks"], new["superblocks"])
    if "tail" in old:
        out["tail"] = jax.tree.map(mrg(0), old["tail"], new["tail"])
    return out


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_ctx: int = 2048
    temperature: float = 0.0
    cache_dtype: str = "float32"
    # GR-MAC backend override for CIM-enabled archs (None keeps the arch's
    # CIMConfig.backend; see kernels.dispatch for the choices)
    cim_backend: Optional[str] = None


class Engine:
    def __init__(self, arch: ArchConfig, params, cfg: ServeConfig):
        assert arch.input_mode == "tokens", "engine serves token models"
        if cfg.cim_backend is not None:
            arch = arch.replace(cim=arch.cim.with_backend(cfg.cim_backend))
        self.arch = arch
        self.cfg = cfg
        self.params = params
        self.cache = init_cache(
            arch, cfg.batch_slots, cfg.max_ctx, jnp.dtype(cfg.cache_dtype))
        self.lengths = np.zeros(cfg.batch_slots, np.int32)
        self.active = np.zeros(cfg.batch_slots, bool)
        self.tokens: List[List[int]] = [[] for _ in range(cfg.batch_slots)]
        self._decode = _decode_fn(self.arch)

    @staticmethod
    def _snapshot(host_state: np.ndarray) -> jax.Array:
        """Immutable device view of mutable per-slot host state.

        ``jnp.asarray(numpy_array)`` is zero-copy on CPU when the buffer is
        aligned, so the jax Array *aliases* ``self.lengths``/``self.active``.
        The engine mutates those in place right after dispatching the decode
        — which executes asynchronously — so without a defensive copy the
        computation can read the post-increment value and write the KV cache
        at the wrong slot position (rare, load-dependent token corruption).
        """
        return jnp.asarray(host_state.copy())

    # ------------------------------------------------------------ prefill
    def add_request(self, prompt: List[int]) -> int:
        """Prefill a free slot token-by-token; returns slot id."""
        free = np.where(~self.active)[0]
        if len(free) == 0:
            raise RuntimeError("no free slots")
        slot = int(free[0])
        self.tokens[slot] = list(prompt)
        self.lengths[slot] = 0
        self.active[slot] = True
        for t in prompt:
            self._advance_slot(slot, t)
        return slot

    def _advance_slot(self, slot: int, token: int):
        # Single-slot update via a batched call with per-slot indices.
        # Other lanes write a placeholder at their own *frozen* position;
        # because their length counter does not move, their next real
        # token overwrites the same slot — no cache merging needed (and
        # merging is a trap: stacked superblock caches carry the batch on
        # axis 1, not axis 0).
        toks = np.zeros((self.cfg.batch_slots, 1), np.int32)
        toks[slot, 0] = token
        logits, new_cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            self._snapshot(self.lengths))
        mask = jnp.zeros(self.cfg.batch_slots, bool).at[slot].set(True)
        self.cache = _merge_cache(self.cache, new_cache, mask)
        self.lengths[slot] += 1
        self._last_logits = logits

    # ------------------------------------------------------------ decode
    def step(self, key: Optional[jax.Array] = None) -> dict:
        """One decode step for every active slot."""
        if not self.active.any():
            return {}
        toks = np.zeros((self.cfg.batch_slots, 1), np.int32)
        for s in range(self.cfg.batch_slots):
            if self.active[s] and self.tokens[s]:
                toks[s, 0] = self.tokens[s][-1]
        # per-slot decode indices: true continuous batching — slots at
        # different generation lengths write/attend at their own positions
        logits, new_cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            self._snapshot(self.lengths))
        self.cache = _merge_cache(
            self.cache, new_cache, self._snapshot(self.active))
        out = {}
        for s in range(self.cfg.batch_slots):
            if not self.active[s]:
                continue  # inactive lanes wrote at their own (frozen) index
            lg = logits[s]
            if self.cfg.temperature > 0 and key is not None:
                key, sub = jax.random.split(key)
                nxt = int(jax.random.categorical(
                    sub, lg / self.cfg.temperature))
            else:
                nxt = int(jnp.argmax(lg))
            self.tokens[s].append(nxt)
            self.lengths[s] += 1
            out[s] = nxt
            if self.lengths[s] >= self.cfg.max_ctx:
                self.active[s] = False
        return out


def energy_report(arch: ArchConfig, seq_len: int = 1) -> dict:
    """Per-token CIM energy (pJ) from the paper's cost model.

    Counts MACs of every projection matmul executed per decoded token and
    prices them at the config's design point (fJ/Op × 2 Ops/MAC).
    """
    if not arch.cim.enabled:
        return {"enabled": False}
    pt = evaluate_point(
        jax.random.PRNGKey(0), arch.cim.fmt_x, arch.cim.fmt_w,
        n_r=arch.cim.n_r, n_cols=1 << 11)
    gr = pt.gr if pt.gr is not None else pt.conv
    fj_per_op = gr.total
    macs = 0
    d = arch.d_model
    for kind in arch.blocks():
        if kind in ("attn", "local"):
            macs += d * (arch.n_heads + 2 * arch.n_kv_heads) * arch.d_head
            macs += arch.n_heads * arch.d_head * d
            ffn = True
        elif kind == "rglru":
            w = arch.rnn_width
            macs += 3 * d * w + w * d
            ffn = True
        elif kind == "ssm":
            macs += d * (2 * arch.d_inner + 2 * arch.ssm_state
                         + arch.ssm_heads) + arch.d_inner * d
            ffn = False
        if ffn and kind != "ssm":
            if arch.is_moe:
                f = arch.expert_d_ff
                nmat = 3 if arch.gated_mlp else 2
                macs += arch.top_k * nmat * d * f + d * arch.n_experts
                if arch.moe_dense_residual:
                    macs += nmat * d * arch.d_ff
            else:
                nmat = 3 if arch.gated_mlp else 2
                macs += nmat * d * arch.d_ff
    macs += d * arch.vocab_size  # LM head
    ops = 2 * macs * seq_len
    return {
        "enabled": True,
        "design": pt.gr_arch,
        "fj_per_op": fj_per_op,
        "enob": pt.enob_gr,
        "ops_per_token": ops,
        "pj_per_token": ops * fj_per_op * 1e-3,
        "conventional_fj_per_op": pt.conv.total if pt.conv else None,
    }
