"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch with chunked, length-bucketed prefill and on-device sampling.

Prefill (``add_request``) pads each prompt chunk to a power-of-two bucket
and runs it through ``models.prefill_step`` — one compiled dispatch per
bucket (so O(ceil(len/bucket_max)) dispatches per prompt, vs one per token
in the legacy ``prefill_mode="token"`` path), with the compile cache
bounded by the O(log max_ctx) distinct bucket lengths per arch. Lanes not
being prefilled are frozen inside the dispatch (length 0), so no host-side
cache merging happens on the prefill path at all.

Decode (``step``) is a single jit'd function over the whole batch that also
performs the per-lane cache merge *and* token selection (greedy argmax or
temperature-categorical) on device: logits never leave the device — the
host sees exactly one device→host transfer of a ``(batch_slots,)`` int32
array of sampled ids per step.

Per-token CIM energy accounting: when the arch config has the GR-CIM path
enabled, ``energy_report`` walks the model dims and prices every projection
matmul with the paper's cost model (fJ/Op) — the deployment metric the
paper optimizes. The underlying DSE Monte-Carlo solve is memoized per
design point.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.dse import evaluate_point
from repro.models import decode_step, init_cache, prefill_step

__all__ = ["ServeConfig", "Engine", "StepResult", "energy_report"]


class StepResult(dict):
    """``Engine.step`` result: slot id -> sampled token (dict, as before),
    plus ``finished`` — the slot ids freed this step (per-slot EOS or
    context exhaustion), in ascending slot order. A finished slot is
    immediately claimable by ``add_request``."""

    def __init__(self, tokens: dict, finished: List[int]):
        super().__init__(tokens)
        self.finished = finished


def _merge_cache(old, new, mask):
    """Per-lane cache merge: lanes where ``mask`` is True take the new
    cache. Attention caches are positionally overwritten anyway, but
    recurrent states (SSM/RG-LRU) mutate on every pass and MUST be frozen
    for lanes that did not really advance. Stacked super-block caches carry
    the batch on axis 1; tail caches on axis 0."""
    def mrg(axis):
        def f(o, n):
            shape = [1] * o.ndim
            shape[axis] = -1
            return jnp.where(jnp.reshape(mask, shape), n, o)
        return f

    out = {}
    if "superblocks" in old:
        out["superblocks"] = jax.tree.map(
            mrg(1), old["superblocks"], new["superblocks"])
    if "tail" in old:
        out["tail"] = jax.tree.map(mrg(0), old["tail"], new["tail"])
    return out


@functools.lru_cache(maxsize=64)
def _decode_fn(arch: ArchConfig, sample: bool):
    """One compiled decode executable per (arch, sampling mode), shared by
    every Engine.

    Sharing (rather than one ``jax.jit`` per Engine) keeps every engine for
    a given arch bitwise-consistent — XLA autotunes each compilation of the
    same HLO independently and a last-ulp logits difference flips greedy
    argmax near ties. The executable fuses the whole per-step hot path:
    decode forward, per-lane active-mask cache merge, and token selection
    (argmax, or per-lane temperature categorical when ``sample``), so
    logits and caches never cross the device boundary.
    """
    def fn(params, toks, cache, lengths, active, key, temp):
        logits, new_cache = decode_step(params, toks, arch, cache, lengths)
        merged = _merge_cache(cache, new_cache, active)
        if sample:
            keys = jax.random.split(key, logits.shape[0])
            nxt = jax.vmap(
                lambda k, lg: jax.random.categorical(k, lg / temp))(
                    keys, logits)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), merged

    return jax.jit(fn)


@functools.lru_cache(maxsize=256)
def _prefill_fn(arch: ArchConfig, bucket: int):
    """One compiled chunked-prefill executable per (arch, bucket length),
    shared by every Engine. Buckets are powers of two (see
    ``Engine._bucket``), so the cache stays O(log max_ctx) per arch."""
    return jax.jit(lambda p, t, c, i, l: prefill_step(p, t, arch, c, i, l))


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_ctx: int = 2048
    temperature: float = 0.0
    cache_dtype: str = "float32"
    # GR-MAC backend override for CIM-enabled archs (None keeps the arch's
    # CIMConfig.backend; see kernels.dispatch for the choices). Decode is a
    # small-M matmul, so "auto" plans onto the batched-einsum xla path;
    # cim_tile_m / cim_tile_n pin the tiled/Pallas tile sizes when set.
    cim_backend: Optional[str] = None
    cim_tile_m: Optional[int] = None
    cim_tile_n: Optional[int] = None
    # Default EOS token id: a lane emitting it is finished and its slot is
    # freed immediately (per-request override via add_request(eos_id=...)).
    # None decodes every lane to max_ctx (the legacy behavior).
    eos_id: Optional[int] = None
    # "bucketed": chunked prefill, prompts padded to power-of-two buckets
    # (the default); "token": legacy one-dispatch-per-token prefill, kept
    # as the equivalence oracle for tests/benchmarks
    prefill_mode: str = "bucketed"
    prefill_bucket_min: int = 8
    prefill_bucket_max: int = 1024


class Engine:
    def __init__(self, arch: ArchConfig, params, cfg: ServeConfig):
        assert arch.input_mode == "tokens", "engine serves token models"
        if cfg.cim_backend is not None:
            arch = arch.replace(cim=arch.cim.with_backend(cfg.cim_backend))
        if cfg.cim_tile_m is not None or cfg.cim_tile_n is not None:
            arch = arch.replace(cim=arch.cim.with_tiles(
                cfg.cim_tile_m, cfg.cim_tile_n))
        self.arch = arch
        self.cfg = cfg
        self.params = params
        self.cache = init_cache(
            arch, cfg.batch_slots, cfg.max_ctx, jnp.dtype(cfg.cache_dtype))
        self.lengths = np.zeros(cfg.batch_slots, np.int32)
        self.active = np.zeros(cfg.batch_slots, bool)
        self.tokens: List[List[int]] = [[] for _ in range(cfg.batch_slots)]
        # last emitted token per lane, fed back as next decode input
        self._last_host = np.zeros(cfg.batch_slots, np.int32)
        # per-slot EOS id (-1: none); seeded from cfg.eos_id per request
        self._eos = np.full(cfg.batch_slots, -1, np.int64)
        # slots that have hosted a request (their cache state is dirty and
        # must be zeroed before reuse)
        self._dirty = np.zeros(cfg.batch_slots, bool)
        self.stats = {"prefill_dispatches": 0, "decode_steps": 0}

    @staticmethod
    def _snapshot(host_state: np.ndarray) -> jax.Array:
        """Immutable device view of mutable per-slot host state.

        ``jnp.asarray(numpy_array)`` is zero-copy on CPU when the buffer is
        aligned, so the jax Array *aliases* ``self.lengths``/``self.active``.
        The engine mutates those in place right after dispatching the decode
        — which executes asynchronously — so without a defensive copy the
        computation can read the post-increment value and write the KV cache
        at the wrong slot position (rare, load-dependent token corruption).
        """
        return jnp.asarray(host_state.copy())

    # ------------------------------------------------------------ prefill
    def add_request(self, prompt: List[int],
                    eos_id: Optional[int] = None) -> int:
        """Prefill a free slot and return its id.

        Bucketed mode splits the prompt into ``prefill_bucket_max``-sized
        chunks, pads the remainder up to a power of two, and issues one
        compiled dispatch per chunk — ``ceil(len / bucket_max)`` dispatches
        (never more than ``ceil(log2(len)) + 1`` for prompts that fit the
        context), vs ``len`` in legacy ``prefill_mode="token"``.

        ``eos_id`` overrides ``cfg.eos_id`` for this request: the lane is
        freed as soon as it emits that token (the EOS itself is kept in
        ``tokens``), making the slot claimable by the next ``add_request``.
        """
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.cfg.max_ctx:
            # strictly less: the first decode step writes the re-fed last
            # prompt token at position len(prompt), which must still be a
            # valid cache index (at len == max_ctx it would clamp onto the
            # last prompt entry and corrupt the lane)
            raise ValueError(
                f"prompt of {len(prompt)} tokens needs max_ctx > "
                f"{len(prompt)} (got {self.cfg.max_ctx}) to decode")
        free = np.where(~self.active)[0]
        if len(free) == 0:
            raise RuntimeError("no free slots")
        slot = int(free[0])
        if self._dirty[slot]:
            self._reset_slot_state(slot)
        self._dirty[slot] = True
        self.tokens[slot] = list(prompt)
        self.lengths[slot] = 0
        self.active[slot] = True
        eos = eos_id if eos_id is not None else self.cfg.eos_id
        self._eos[slot] = -1 if eos is None else int(eos)
        if self.cfg.prefill_mode == "token":
            for t in prompt:
                self._advance_slot(slot, t)
        else:
            pos = 0
            while pos < len(prompt):
                chunk = prompt[pos:pos + self.cfg.prefill_bucket_max]
                self._prefill_chunk(slot, chunk)
                pos += len(chunk)
        self._last_host[slot] = prompt[-1]
        return slot

    def _reset_slot_state(self, slot: int):
        """Zero one lane's cache before a freed slot hosts a new request.

        Attention KV is positionally overwritten and length-masked, so it
        cannot leak — but RG-LRU/SSM recurrent states persist across the
        request boundary and would seed the new prompt's prefill scan with
        the previous occupant's state."""
        def z(axis):
            def f(a):
                idx = [slice(None)] * a.ndim
                idx[axis] = slot
                return a.at[tuple(idx)].set(0)
            return f
        out = dict(self.cache)
        if "superblocks" in out:
            out["superblocks"] = jax.tree.map(z(1), out["superblocks"])
        if "tail" in out:
            out["tail"] = jax.tree.map(z(0), out["tail"])
        self.cache = out

    def _bucket(self, n: int) -> int:
        b = self.cfg.prefill_bucket_min
        while b < n:
            b *= 2
        return b

    def _prefill_chunk(self, slot: int, chunk: List[int]):
        """One bucketed prefill dispatch: the chunk is right-padded to its
        bucket and every other lane rides along frozen (length 0), so the
        returned cache is adopted wholesale — no merge."""
        bucket = self._bucket(len(chunk))
        toks = np.zeros((self.cfg.batch_slots, bucket), np.int32)
        toks[slot, :len(chunk)] = chunk
        lens = np.zeros(self.cfg.batch_slots, np.int32)
        lens[slot] = len(chunk)
        fill = _prefill_fn(self.arch, bucket)
        _, self.cache = fill(
            self.params, jnp.asarray(toks), self.cache,
            self._snapshot(self.lengths), jnp.asarray(lens))
        self.lengths[slot] += len(chunk)
        self.stats["prefill_dispatches"] += 1

    def _advance_slot(self, slot: int, token: int):
        # Legacy token-by-token prefill: a batched decode call with per-slot
        # indices, all lanes but ``slot`` masked out of the cache merge.
        toks = np.zeros((self.cfg.batch_slots, 1), np.int32)
        toks[slot, 0] = token
        mask = np.zeros(self.cfg.batch_slots, bool)
        mask[slot] = True
        _, self.cache = _decode_fn(self.arch, False)(
            self.params, jnp.asarray(toks), self.cache,
            self._snapshot(self.lengths), jnp.asarray(mask),
            jax.random.PRNGKey(0), 1.0)
        self.lengths[slot] += 1
        self.stats["prefill_dispatches"] += 1

    # ------------------------------------------------------------ decode
    def step(self, key: Optional[jax.Array] = None) -> "StepResult":
        """One decode step for every active slot.

        The compiled decode returns only the sampled token ids; everything
        else (logits, cache merge, sampling) stays on device. Pass ``key``
        (and set ``temperature > 0``) for per-lane categorical sampling;
        greedy argmax otherwise.

        Returns a ``StepResult`` (a dict of slot id -> token, exactly as
        before) whose ``finished`` attribute lists the slots freed this
        step — lanes that emitted their EOS or ran out of context. Freed
        slots drop out of the active mask (their caches freeze inside the
        fused decode) and are immediately claimable by ``add_request``.
        """
        if not self.active.any():
            return StepResult({}, [])
        sample = self.cfg.temperature > 0 and key is not None
        fn = _decode_fn(self.arch, sample)
        ids_dev, self.cache = fn(
            self.params, self._snapshot(self._last_host[:, None]),
            self.cache, self._snapshot(self.lengths),
            self._snapshot(self.active),
            key if key is not None else jax.random.PRNGKey(0),
            float(self.cfg.temperature) if sample else 1.0)
        ids = self._fetch(ids_dev)
        act = np.where(self.active)[0]
        out = {}
        for s in act:
            t = int(ids[s])
            self.tokens[s].append(t)
            out[int(s)] = t
        self._last_host[act] = ids[act]
        self.lengths[act] += 1
        # Per-slot completion: emitted EOS, or no context left for another
        # decode write. Either way the slot leaves the active mask (its
        # cache freezes in the next fused decode) and is free to reuse.
        hit_eos = (self._eos >= 0) & (self._last_host == self._eos)
        done = self.active & (hit_eos | (self.lengths >= self.cfg.max_ctx))
        finished = [int(s) for s in np.where(done)[0]]
        self.active[done] = False
        self.stats["decode_steps"] += 1
        return StepResult(out, finished)

    @staticmethod
    def _fetch(ids_dev: jax.Array) -> np.ndarray:
        """The single device→host transfer per decode step: the sampled
        (batch_slots,) int32 token ids."""
        return np.asarray(ids_dev)


@functools.lru_cache(maxsize=64)
def _energy_point(fmt_x, fmt_w, n_r, n_cols, seed):
    """Memoized ``evaluate_point``: the required-ENOB solve behind it runs
    a full Monte-Carlo per call, but is fully determined by the CIM design
    knobs *and the sampling configuration* — the RNG seed and the sample
    count are part of the cache key, so a changed sampling setup can never
    be served a stale memoized solve."""
    return evaluate_point(
        jax.random.PRNGKey(seed), fmt_x, fmt_w, n_r=n_r, n_cols=n_cols)


def energy_report(arch: ArchConfig, seq_len: int = 1, *,
                  seed: int = 0, n_cols: int = 1 << 11) -> dict:
    """Per-token CIM energy (pJ) from the paper's cost model.

    Counts MACs of every projection matmul executed per decoded token and
    prices them at the config's design point (fJ/Op × 2 Ops/MAC).
    ``seed``/``n_cols`` configure the underlying Monte-Carlo ENOB solve
    (both participate in its memoization key).
    """
    if not arch.cim.enabled:
        return {"enabled": False}
    pt = _energy_point(arch.cim.fmt_x, arch.cim.fmt_w, arch.cim.n_r,
                       n_cols, seed)
    gr = pt.gr if pt.gr is not None else pt.conv
    fj_per_op = gr.total
    macs = 0
    d = arch.d_model
    for kind in arch.blocks():
        if kind in ("attn", "local"):
            macs += d * (arch.n_heads + 2 * arch.n_kv_heads) * arch.d_head
            macs += arch.n_heads * arch.d_head * d
            ffn = True
        elif kind == "rglru":
            w = arch.rnn_width
            macs += 3 * d * w + w * d
            ffn = True
        elif kind == "ssm":
            macs += d * (2 * arch.d_inner + 2 * arch.ssm_state
                         + arch.ssm_heads) + arch.d_inner * d
            ffn = False
        if ffn and kind != "ssm":
            if arch.is_moe:
                f = arch.expert_d_ff
                nmat = 3 if arch.gated_mlp else 2
                macs += arch.top_k * nmat * d * f + d * arch.n_experts
                if arch.moe_dense_residual:
                    macs += nmat * d * arch.d_ff
            else:
                nmat = 3 if arch.gated_mlp else 2
                macs += nmat * d * arch.d_ff
    macs += d * arch.vocab_size  # LM head
    ops = 2 * macs * seq_len
    return {
        "enabled": True,
        "design": pt.gr_arch,
        "fj_per_op": fj_per_op,
        "enob": pt.enob_gr,
        "ops_per_token": ops,
        "pj_per_token": ops * fj_per_op * 1e-3,
        "conventional_fj_per_op": pt.conv.total if pt.conv else None,
    }
