"""Request-level serving API types: ``SamplingParams`` and
``RequestOutput``.

``SamplingParams`` is the single way per-request knobs enter the system —
``Engine.add_request``/``begin_request`` and ``Scheduler.submit`` accept
one instead of scattered kwargs (the legacy ``eos_id=``/
``max_new_tokens=`` kwargs are still accepted for one release under a
``DeprecationWarning`` and are converted to an equivalent
``SamplingParams``, bit-identically — tested in
tests/test_sampling_params.py).

Every field defaults to "inherit the engine/scheduler default", so
``SamplingParams()`` is always a valid no-op:

* ``temperature`` — per-request sampling temperature; ``None`` inherits
  ``ServeConfig.temperature``. ``0.0`` forces greedy argmax for this
  request even inside a sampled batch (the fused decode applies
  temperatures per lane).
* ``seed`` — per-request PRNG seed. A seeded request derives its lane
  key as ``fold_in(PRNGKey(seed), event_counter)`` inside the decode
  executable, so its sampled stream is reproducible regardless of which
  slot it lands in or what other traffic shares the batch (the unseeded
  path splits the caller's per-step key across lanes, as before).
  Seeded requests sample even when the caller passes no per-step key.
* ``eos_id`` — per-request stop token; ``None`` inherits
  ``ServeConfig.eos_id``.
* ``max_tokens`` — cap on *generated* tokens (including the
  prefill-sampled first one). Enforced inside the engine: the lane is
  freed with finish reason ``"length"`` the step it reaches the cap.
  ``None`` decodes until EOS / context exhaustion.
* ``spec_k`` — speculative-decode lookahead for this request when a
  ``serving.speculative.SpecDecoder`` drives the batch: ``None``
  inherits the decoder's ``SpecConfig.k``; ``1`` opts the request out
  (plain sequential decode); ``k >= 2`` drafts ``k - 1`` tokens per
  iteration. Ignored under plain ``Engine.step``.

``RequestOutput`` is the typed per-request slice of a decode iteration —
``StepResult.outputs`` carries one per live request, replacing the
ad-hoc dict poking the benches used to do on the raw slot->token dict
(which remains, for compatibility). ``tokens`` holds every token the
request emitted *this step* (speculative steps emit several), so
consumers sum ``len(out.tokens)`` for throughput and read
``finish_reason`` instead of re-deriving EOS/length/ctx from engine
internals.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

__all__ = ["SamplingParams", "RequestOutput"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: Optional[float] = None
    seed: Optional[int] = None
    eos_id: Optional[int] = None
    max_tokens: Optional[int] = None
    spec_k: Optional[int] = None

    def __post_init__(self):
        if self.temperature is not None and self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if self.max_tokens is not None and self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got "
                             f"{self.max_tokens}")
        if self.spec_k is not None and self.spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")

    def replace(self, **kw) -> "SamplingParams":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class RequestOutput:
    """One request's slice of a decode iteration (``StepResult.outputs``).

    ``tokens`` are the tokens emitted this step in order (possibly empty
    for a completion surfaced from prefill time, possibly several under
    speculative decode); ``finished``/``finish_reason`` report terminal
    state (``"eos"`` / ``"length"`` / ``"ctx"``); ``pj_per_token`` is the
    decode-phase CIM energy per generated token (lazy thunk into the
    engine's memo; None off the CIM path)."""
    slot: int
    tokens: List[int]
    finished: bool = False
    finish_reason: Optional[str] = None
    _energy_fn: Optional[callable] = None

    @property
    def pj_per_token(self) -> Optional[float]:
        return self._energy_fn() if self._energy_fn is not None else None
