from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill_step,
    train_loss,
)

__all__ = ["init_params", "init_cache", "forward", "train_loss",
           "decode_step", "prefill_step"]
