"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    r_t = sigmoid(W_r u_t),  i_t = sigmoid(W_i u_t)
    a_t = exp(-c · softplus(Λ) · r_t)            (per-channel gated decay)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
    out = W_o h_t

preceded by a width-``conv_width`` causal depthwise conv on the x branch.
Train path uses an associative scan over time; decode is the recurrence.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense, init_dense
from repro.models.ssm import _causal_conv
from repro.parallel.sharding import shard

__all__ = ["init_rglru", "rglru_train", "rglru_decode", "init_rglru_state"]

_C = 8.0


def init_rglru(key, cfg: ArchConfig, dtype):
    d, w = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 5)
    return {
        "in_proj": init_dense(ks[0], d, w, dtype),
        "gate_r": init_dense(ks[1], d, w, dtype),
        "gate_i": init_dense(ks[2], d, w, dtype),
        "conv": (0.1 * jax.random.normal(ks[3], (cfg.conv_width, w))).astype(dtype),
        # Λ init so that a^c spans (0.9, 0.999) at r=1 (paper's stable range)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / _C
        )).astype(jnp.float32),
        "out_proj": init_dense(ks[4], w, d, dtype,
                               scale=1.0 / math.sqrt(w * 2 * cfg.n_layers)),
    }


def init_rglru_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.rnn_width), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width), dtype),
    }


def _branches(p, u, cfg: ArchConfig):
    x = dense(p["in_proj"], u, cfg.cim, "qkvo")
    r = jax.nn.sigmoid(dense(p["gate_r"], u, cfg.cim, "qkvo").astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["gate_i"], u, cfg.cim, "qkvo").astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"])[None, None, :] * r   # (B,S,W) ≤ 0
    return x, i, log_a


def rglru_train(p, u: jax.Array, cfg: ArchConfig) -> jax.Array:
    b, s, d = u.shape
    x, i, log_a = _branches(p, u, cfg)
    x = _causal_conv(x, p["conv"].astype(x.dtype))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (
        i * x.astype(jnp.float32)
    )

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h = shard(h.astype(u.dtype), "data", None, "model")
    return dense(p["out_proj"], h, cfg.cim, "qkvo")


def rglru_decode(
    p, u: jax.Array, cfg: ArchConfig, state: dict
) -> Tuple[jax.Array, dict]:
    b, s, d = u.shape
    assert s == 1
    x, i, log_a = _branches(p, u, cfg)
    win = jnp.concatenate([state["conv"], x.astype(state["conv"].dtype)], axis=1)
    kernel = p["conv"].astype(jnp.float32)
    xc = jnp.sum(win * kernel[None, :, :], axis=1)               # (B, W)
    new_conv = win[:, 1:, :]
    a = jnp.exp(log_a[:, 0, :])
    h_new = a * state["h"] + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (
        i[:, 0, :] * xc
    )
    out = dense(p["out_proj"], h_new[:, None, :].astype(u.dtype),
                cfg.cim, "qkvo")
    return out, {"h": h_new, "conv": new_conv}
