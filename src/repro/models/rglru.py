"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    r_t = sigmoid(W_r u_t),  i_t = sigmoid(W_i u_t)
    a_t = exp(-c · softplus(Λ) · r_t)            (per-channel gated decay)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
    out = W_o h_t

preceded by a width-``conv_width`` causal depthwise conv on the x branch.
Train path uses an associative scan over time; decode is the recurrence;
prefill (``rglru_prefill``) runs the decode recurrence over a whole prompt
chunk inside one ``lax.scan`` so a bucketed prefill stays numerically on
top of the token-by-token path (same per-step elementwise math).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense, init_dense
from repro.models.ssm import _causal_conv
from repro.parallel.sharding import shard

__all__ = ["init_rglru", "rglru_train", "rglru_decode", "rglru_prefill",
           "init_rglru_state"]

_C = 8.0


def init_rglru(key, cfg: ArchConfig, dtype):
    d, w = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 5)
    return {
        "in_proj": init_dense(ks[0], d, w, dtype),
        "gate_r": init_dense(ks[1], d, w, dtype),
        "gate_i": init_dense(ks[2], d, w, dtype),
        "conv": (0.1 * jax.random.normal(ks[3], (cfg.conv_width, w))).astype(dtype),
        # Λ init so that a^c spans (0.9, 0.999) at r=1 (paper's stable range)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / _C
        )).astype(jnp.float32),
        "out_proj": init_dense(ks[4], w, d, dtype,
                               scale=1.0 / math.sqrt(w * 2 * cfg.n_layers)),
    }


def init_rglru_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.rnn_width), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width), dtype),
    }


def _branches(p, u, cfg: ArchConfig):
    x = dense(p["in_proj"], u, cfg.cim, "rglru")
    r = jax.nn.sigmoid(dense(p["gate_r"], u, cfg.cim, "rglru").astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["gate_i"], u, cfg.cim, "rglru").astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"])[None, None, :] * r   # (B,S,W) ≤ 0
    return x, i, log_a


def rglru_train(p, u: jax.Array, cfg: ArchConfig) -> jax.Array:
    b, s, d = u.shape
    x, i, log_a = _branches(p, u, cfg)
    x = _causal_conv(x, p["conv"].astype(x.dtype))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (
        i * x.astype(jnp.float32)
    )

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h = shard(h.astype(u.dtype), "data", None, "model")
    return dense(p["out_proj"], h, cfg.cim, "rglru")


def _recurrence_step(kernel, h, win, x_t, i_t, log_a_t):
    """One RG-LRU time step from (h, conv window) — the single source of
    the per-token update shared by decode and prefill, so the bucketed
    prefill's bitwise-equivalence contract can't drift from the decode
    math. x_t/i_t/log_a_t: (B, W) slices. Returns (h_new, win_new)."""
    win_full = jnp.concatenate([win, x_t[:, None, :].astype(win.dtype)],
                               axis=1)
    xc = jnp.sum(win_full * kernel[None, :, :], axis=1)          # (B, W)
    a = jnp.exp(log_a_t)
    h_new = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i_t * xc)
    return h_new, win_full[:, 1:, :]


def rglru_decode(
    p, u: jax.Array, cfg: ArchConfig, state: dict
) -> Tuple[jax.Array, dict]:
    b, s, d = u.shape
    assert s == 1
    x, i, log_a = _branches(p, u, cfg)
    kernel = p["conv"].astype(jnp.float32)
    h_new, new_conv = _recurrence_step(
        kernel, state["h"], state["conv"], x[:, 0, :], i[:, 0, :],
        log_a[:, 0, :])
    out = dense(p["out_proj"], h_new[:, None, :].astype(u.dtype),
                cfg.cim, "rglru")
    return out, {"h": h_new, "conv": new_conv}


def rglru_prefill(
    p, u: jax.Array, cfg: ArchConfig, state: dict, length: jax.Array
) -> Tuple[jax.Array, dict]:
    """Chunked prefill: the decode recurrence over u (B, S, D) in one pass.

    ``length`` (B,) counts the valid leading tokens per lane; steps at
    ``t >= length`` are identity updates (state and conv window frozen), so
    right-padded buckets and untouched lanes (length 0) leave ``state``
    bitwise unchanged. Each step is ``_recurrence_step`` — the same op
    sequence as ``rglru_decode`` — driven by ``lax.scan`` instead of one
    dispatch per token.
    """
    b, s, d = u.shape
    x, i, log_a = _branches(p, u, cfg)
    kernel = p["conv"].astype(jnp.float32)
    valid = jnp.arange(s)[None, :] < length[:, None]             # (B, S)

    def step(carry, t_in):
        h, win = carry
        x_t, i_t, la_t, v_t = t_in
        h_new, win_new = _recurrence_step(kernel, h, win, x_t, i_t, la_t)
        h_new = jnp.where(v_t[:, None], h_new, h)
        win_new = jnp.where(v_t[:, None, None], win_new, win)
        return (h_new, win_new), h_new

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(i, 1, 0),
          jnp.moveaxis(log_a, 1, 0), jnp.moveaxis(valid, 1, 0))
    (h_last, win_last), h_seq = jax.lax.scan(
        step, (state["h"], state["conv"]), xs)
    h_seq = jnp.moveaxis(h_seq, 0, 1).astype(u.dtype)            # (B, S, W)
    out = dense(p["out_proj"], h_seq, cfg.cim, "rglru")
    return out, {"h": h_last, "conv": win_last}
