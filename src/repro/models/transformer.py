"""Model assembly: embeddings + a stack of blocks (attn/local/rglru/ssm with
dense-or-MoE FFNs) + LM head, for all ten assigned architectures.

Depth is organized as *super-blocks* of ``len(cfg.block_pattern)`` layers.
Full super-blocks are scanned (``jax.lax.scan`` over stacked params) so the
lowered HLO is O(pattern period), not O(depth) — essential for compiling
512-way-sharded 35..64-layer models; remainder layers run unrolled.

Three execution paths share the layer code:
  train            full-sequence, no caches
  chunked prefill  full prompt chunk against per-layer caches/states, KV and
                   recurrent state written at per-lane offsets in one
                   dispatch (``prefill_step``; right-padding masked out)
  decode           single token against per-layer caches/states
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.ops import cim_matmul
from repro.models import layers as L
from repro.models.moe import init_moe, moe
from repro.models.rglru import (
    init_rglru, init_rglru_state, rglru_decode, rglru_prefill, rglru_train)
from repro.models.ssm import (
    init_ssm, init_ssm_state, ssm_decode, ssm_prefill, ssm_train)
from repro.parallel.sharding import shard

__all__ = [
    "init_params",
    "init_cache",
    "forward",
    "train_loss",
    "decode_step",
    "prefill_step",
]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _ckpt(fn, cfg: ArchConfig, static_argnums=()):
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, static_argnums=static_argnums,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, static_argnums=static_argnums)


# ------------------------------------------------------------------ init
def _init_layer(key, kind: str, cfg: ArchConfig):
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p: dict = {"norm1": L.init_rmsnorm(d, dt)}
    if kind in ("attn", "local"):
        p["attn"] = L.init_attention(ks[0], cfg, dt)
    elif kind == "rglru":
        p["rglru"] = init_rglru(ks[0], cfg, dt)
    elif kind == "ssm":
        p["ssm"] = init_ssm(ks[0], cfg, dt)
        return p  # Mamba2 block has no separate FFN
    else:
        raise ValueError(kind)
    p["norm2"] = L.init_rmsnorm(d, dt)
    if cfg.is_moe:
        p["moe"] = init_moe(ks[1], cfg, dt)
    else:
        p["ffn"] = L.init_mlp(ks[1], d, cfg.d_ff, cfg, dt)
    return p


def _init_superblock(key, cfg: ArchConfig):
    pat = cfg.block_pattern
    ks = jax.random.split(key, len(pat))
    return {f"b{i}_{kind}": _init_layer(ks[i], kind, cfg)
            for i, kind in enumerate(pat)}


def init_params(key, cfg: ArchConfig):
    dt = _dtype(cfg)
    period = cfg.pattern_period()
    n_super, n_tail = divmod(cfg.n_layers, period)
    keys = jax.random.split(key, 4)

    params: dict = {}
    if cfg.input_mode == "tokens":
        params["embed"] = (
            0.02 * jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model))
        ).astype(dt)
    if n_super:
        sb_keys = jax.random.split(keys[1], n_super)
        params["superblocks"] = jax.vmap(
            lambda k: _init_superblock(k, cfg)
        )(sb_keys)
    if n_tail:
        tail_keys = jax.random.split(keys[2], n_tail)
        pat = cfg.block_pattern
        params["tail"] = {
            f"t{i}_{pat[i]}": _init_layer(tail_keys[i], pat[i], cfg)
            for i in range(n_tail)
        }
    params["final_norm"] = L.init_rmsnorm(cfg.d_model, dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(
            keys[3], cfg.d_model, cfg.padded_vocab, dt)
    return params


# ------------------------------------------------------------------ caches
def init_cache(cfg: ArchConfig, batch: int, ctx_len: int, dtype=jnp.bfloat16):
    """Per-layer decode caches, grouped like the params (stacked + tail)."""

    def one(kind):
        if kind == "attn":
            shape = (batch, ctx_len, cfg.n_kv_heads, cfg.d_head)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if kind == "local":
            shape = (batch, min(cfg.window, ctx_len), cfg.n_kv_heads, cfg.d_head)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if kind == "rglru":
            return init_rglru_state(cfg, batch)
        if kind == "ssm":
            return init_ssm_state(cfg, batch)
        raise ValueError(kind)

    pat = cfg.block_pattern
    period = cfg.pattern_period()
    n_super, n_tail = divmod(cfg.n_layers, period)
    cache: dict = {}
    if n_super:
        sb = {f"b{i}_{kind}": one(kind) for i, kind in enumerate(pat)}
        cache["superblocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_super, *x.shape)).copy(), sb)
    if n_tail:
        cache["tail"] = {f"t{i}_{pat[i]}": one(pat[i]) for i in range(n_tail)}
    return cache


# ------------------------------------------------------------------ blocks
def _apply_layer(
    kind: str,
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    cache: Optional[dict],
    cache_index,
    chunk_lengths=None,
) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    """Pre-norm residual block. Returns (x, aux_loss, new_cache).

    ``chunk_lengths`` (B,) switches cached execution from single-token
    decode to chunked prefill: per-lane counts of valid leading tokens in
    the S axis (padding/untouched lanes frozen)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(p["norm1"], x)
    new_cache = None
    valid = None
    if chunk_lengths is not None and cfg.is_moe:
        valid = jnp.arange(x.shape[1])[None, :] < chunk_lengths[:, None]
    if kind in ("attn", "local"):
        out, new_cache = L.attention(
            p["attn"], h, cfg, local=(kind == "local"), positions=positions,
            cache=cache, cache_index=cache_index, chunk_lengths=chunk_lengths)
        x = x + out
        h2 = L.rmsnorm(p["norm2"], x)
        if cfg.is_moe:
            out2, aux = moe(p["moe"], h2, cfg, valid=valid)
        else:
            out2 = L.mlp(p["ffn"], h2, cfg)
        x = x + out2
    elif kind == "rglru":
        if cache is None:
            out = rglru_train(p["rglru"], h, cfg)
        elif chunk_lengths is not None:
            out, new_cache = rglru_prefill(p["rglru"], h, cfg, cache,
                                           chunk_lengths)
        else:
            out, new_cache = rglru_decode(p["rglru"], h, cfg, cache)
        x = x + out
        h2 = L.rmsnorm(p["norm2"], x)
        if cfg.is_moe:
            out2, aux = moe(p["moe"], h2, cfg, valid=valid)
        else:
            out2 = L.mlp(p["ffn"], h2, cfg)
        x = x + out2
    elif kind == "ssm":
        if cache is None:
            out = ssm_train(p["ssm"], h, cfg)
        elif chunk_lengths is not None:
            out, new_cache = ssm_prefill(p["ssm"], h, cfg, cache,
                                         chunk_lengths)
        else:
            out, new_cache = ssm_decode(p["ssm"], h, cfg, cache)
        x = x + out
    else:
        raise ValueError(kind)
    return x, aux, new_cache


def _apply_superblock(p_sb, x, cfg, positions, cache_sb, cache_index,
                      chunk_lengths=None):
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {} if cache_sb is not None else None
    for i, kind in enumerate(cfg.block_pattern):
        name = f"b{i}_{kind}"
        c = cache_sb[name] if cache_sb is not None else None
        x, aux, nc = _apply_layer(
            kind, p_sb[name], x, cfg, positions, c, cache_index,
            chunk_lengths)
        aux_total = aux_total + aux
        if new_caches is not None:
            new_caches[name] = nc
    return x, aux_total, new_caches


# ------------------------------------------------------------------ forward
def forward(
    params: dict,
    inputs: jax.Array,
    cfg: ArchConfig,
    *,
    cache: Optional[dict] = None,
    cache_index=None,
    positions: Optional[jax.Array] = None,
    chunk_lengths: Optional[jax.Array] = None,
):
    """Returns (logits, aux_loss, new_cache).

    ``inputs``: int32 token ids (B, S) — or f32/bf16 embeddings (B, S, D)
    when ``cfg.input_mode == "embeddings"`` (modality-stub archs).
    ``chunk_lengths`` (B,) turns a cached call into a chunked prefill over
    the whole S axis (see ``prefill_step``).
    """
    if cfg.input_mode == "tokens":
        x = params["embed"][inputs].astype(_dtype(cfg))
    else:
        x = inputs.astype(_dtype(cfg))
    b, s = x.shape[:2]
    x = shard(x, "data", None, None)

    if positions is None:
        if cache is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        else:
            # scalar or per-sequence (B,) decode/prefill offset
            idx = jnp.broadcast_to(jnp.asarray(cache_index), (b,))
            positions = idx[:, None] + jnp.arange(s)[None, :]

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    if "superblocks" in params:
        p_stack = params["superblocks"]
        n_super = jax.tree.leaves(p_stack)[0].shape[0]

        if cache is None and not cfg.scan_layers:
            # Unrolled depth: O(n_layers) HLO, used by the roofline pass
            # because cost_analysis counts scan bodies exactly once.
            for i in range(n_super):
                p_sb = jax.tree.map(lambda a: a[i], p_stack)
                blk = (_ckpt(_apply_superblock, cfg, static_argnums=(2,))
                       if cfg.remat else _apply_superblock)
                x, aux_sb, _ = blk(p_sb, x, cfg, positions, None, cache_index)
                x = shard(x, "data", None, "model")
                aux_total = aux_total + aux_sb
        elif cache is None:
            def body(carry, p_sb):
                x, aux = carry
                xo, aux_sb, _ = _apply_superblock(
                    p_sb, x, cfg, positions, None, cache_index)
                # keep the saved remat residual 2D-sharded (data × model):
                # un-sharded D made the (L, B, S, D) scan residual stack the
                # second-largest buffer in mamba2 train (§Perf P1)
                xo = shard(xo, "data", None, "model")
                return (xo, aux + aux_sb), None

            body = _ckpt(body, cfg) if cfg.remat else body
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), p_stack)
        elif not cfg.scan_layers:
            c_stack = cache["superblocks"]
            ncs = []
            for i in range(n_super):
                p_sb = jax.tree.map(lambda a: a[i], p_stack)
                c_sb = jax.tree.map(lambda a: a[i], c_stack)
                x, aux_sb, nc = _apply_superblock(
                    p_sb, x, cfg, positions, c_sb, cache_index, chunk_lengths)
                aux_total = aux_total + aux_sb
                ncs.append(nc)
            new_cache["superblocks"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *ncs)
        else:
            c_stack = cache["superblocks"]

            def body(carry, inp):
                x, aux = carry
                p_sb, c_sb = inp
                xo, aux_sb, nc = _apply_superblock(
                    p_sb, x, cfg, positions, c_sb, cache_index, chunk_lengths)
                return (xo, aux + aux_sb), nc

            (x, aux_total), nc_stack = jax.lax.scan(
                body, (x, aux_total), (p_stack, c_stack))
            new_cache["superblocks"] = nc_stack

    if "tail" in params:
        new_tail = {}
        for name, p_l in params["tail"].items():
            kind = name.split("_", 1)[1]
            c = cache["tail"][name] if cache is not None else None
            x, aux, nc = _apply_layer(
                kind, p_l, x, cfg, positions, c, cache_index, chunk_lengths)
            aux_total = aux_total + aux
            new_tail[name] = nc
        if cache is not None:
            new_cache["tail"] = new_tail

    x = L.rmsnorm(params["final_norm"], x)
    # LM head routes through the CIM op in both tied and untied form, so
    # "head" is a real policy site on every arch; the ledger records the
    # true vocab_size (pad columns are masked, never mapped to an array)
    if cfg.tie_embeddings:
        logits = cim_matmul(x, params["embed"].T.astype(x.dtype), cfg.cim,
                            site="head", logical_n=cfg.vocab_size)
    else:
        logits = L.dense(params["lm_head"], x, cfg.cim, "head",
                         logical_n=cfg.vocab_size)
    logits = shard(logits, "data", None, "model")
    if cfg.padded_vocab != cfg.vocab_size:
        # mask pad-vocab columns (fused elementwise; keeps the model-axis
        # sharding that vocab padding buys — §Perf iteration P1)
        pad = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1) >= cfg.vocab_size
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return logits, aux_total, (new_cache if cache is not None else None)


# ------------------------------------------------------------------ losses
def train_loss(params, batch: dict, cfg: ArchConfig, aux_weight: float = 0.01):
    """batch: {"inputs": tokens or embeddings, "labels": (B,S) int32}."""
    logits, aux, _ = forward(params, batch["inputs"], cfg)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        loss = jnp.mean(nll)
    else:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux, "total": total}


def decode_step(params, token, cfg: ArchConfig, cache, cache_index):
    """One decode step: token (B, 1) [or (B, 1, D) embeddings] -> logits.

    ``cache_index`` is a scalar or per-sequence (B,) int32 vector — the
    latter enables continuous batching with slots at different lengths."""
    logits, _, new_cache = forward(
        params, token, cfg, cache=cache, cache_index=cache_index)
    return logits[:, -1, :], new_cache


def prefill_step(params, tokens, cfg: ArchConfig, cache, cache_index, length):
    """Chunked prefill: tokens (B, S) [or (B, S, D) embeddings] -> the
    logits at each lane's last valid token, (B, V), the greedy token ids
    at *every* chunk position, (B, S) int32, plus the new cache.

    ``cache_index`` (scalar or (B,)) is each lane's write offset; ``length``
    (B,) counts the valid leading tokens of this chunk per lane — the S axis
    may be right-padded to a compile-cache-friendly bucket. A lane with
    ``length == 0`` passes through completely frozen: its KV cache,
    recurrent states and conv windows come back bitwise unchanged, so the
    serving engine prefills one slot of a live batch without any host-side
    cache merging. One compiled dispatch replaces ``length`` token-by-token
    decode dispatches; attention runs chunk-parallel while RG-LRU/SSM states
    advance under an in-graph ``lax.scan`` of the exact decode recurrence.

    The per-position ids row is what lets speculative decoding
    (``repro.serving.speculative``) reuse THIS executable as its exact
    verifier: position ``j``'s id is the greedy continuation of the lane's
    context through chunk token ``j``, so scoring k drafted tokens is one
    bucketed prefill dispatch whose ids either confirm each draft or supply
    the correction. The argmax is a tiny fused reduction (pad-vocab columns
    are already masked to -1e30 above) and the (B, S) int32 row stays on
    device unless fetched.
    """
    b, s = tokens.shape[0], tokens.shape[1]
    idx = jnp.broadcast_to(jnp.asarray(cache_index), (b,))
    length = jnp.broadcast_to(jnp.asarray(length), (b,))
    positions = idx[:, None] + jnp.arange(s)[None, :]
    logits, _, new_cache = forward(
        params, tokens, cfg, cache=cache, cache_index=idx,
        positions=positions, chunk_lengths=length)
    last = jnp.clip(length - 1, 0, s - 1)
    last_logits = jnp.take_along_axis(logits, last[:, None, None], axis=1)
    ids = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return last_logits[:, 0, :], ids, new_cache
