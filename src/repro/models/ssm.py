"""Mamba2 SSD (state-space duality) block — chunked parallel algorithm.

Per head h with scalar decay a_t = exp(-dt_t · A_h):

    H_t = a_t · H_{t-1} + dt_t · B_t ⊗ x_t          (N × P state)
    y_t = C_tᵀ H_t + D_h · x_t

The chunked algorithm (arXiv:2405.21060 §6) materializes only S/chunk
states: within a chunk the dual quadratic (attention-like) form computes
the intra-chunk contribution; a scan over chunk summaries carries state.
Train path is fully parallel; decode path is the O(1) recurrence.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense, init_dense, init_rmsnorm, rmsnorm
from repro.parallel.sharding import shard

__all__ = ["init_ssm", "ssm_train", "ssm_decode", "ssm_prefill",
           "init_ssm_state"]


def init_ssm(key, cfg: ArchConfig, dtype):
    d, di, n, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 6)
    return {
        # projections: x -> [z (gate), x_in], plus B, C, dt heads
        "in_proj": init_dense(ks[0], d, 2 * di, dtype),
        "bc_proj": init_dense(ks[1], d, 2 * n, dtype),
        "dt_proj": init_dense(ks[2], d, nh, dtype),
        "conv": (0.1 * jax.random.normal(ks[3], (cfg.conv_width, di))).astype(dtype),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),  # per-head decay rate
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_norm": init_rmsnorm(di, dtype),
        "out_proj": init_dense(ks[4], di, d, dtype,
                               scale=1.0 / math.sqrt(di * 2 * cfg.n_layers)),
    }


def init_ssm_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """Depthwise causal conv along time. x: (B,S,C); kernel: (W,C)."""
    w = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(w):
        out = out + xp[:, i : i + x.shape[1], :] * kernel[i][None, None, :]
    return out


def _project(p, u, cfg: ArchConfig):
    """Shared projection head. u: (B,S,D) -> z, x, B, C, dt."""
    di, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    zx = dense(p["in_proj"], u, cfg.cim, "ssm")
    z, x = jnp.split(zx, [di], axis=-1)
    bc = dense(p["bc_proj"], u, cfg.cim, "ssm").astype(jnp.float32)
    bmat, cmat = jnp.split(bc, [n], axis=-1)                     # (B,S,N) each
    dt = dense(p["dt_proj"], u, cfg.cim, "ssm").astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"][None, None, :])       # (B,S,NH)
    return z, x, bmat, cmat, dt


def ssm_train(p, u: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Full-sequence SSD. u: (B,S,D) -> (B,S,D)."""
    b, s, d = u.shape
    di, n, nh, hd, ck = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                         cfg.ssm_headdim, cfg.ssm_chunk)
    assert s % ck == 0, f"seq {s} must be a multiple of ssm_chunk {ck}"
    nc = s // ck

    z, x, bmat, cmat, dt = _project(p, u, cfg)
    x = _causal_conv(x, p["conv"].astype(x.dtype))
    x = jax.nn.silu(x)
    xh = x.reshape(b, s, nh, hd).astype(jnp.float32)             # heads
    # SSD is embarrassingly parallel over heads: shard NH over "model"
    # (batch over data). Without this the (B,NC,CK,CK,NH) decay tensor is
    # replicated across the TP axis — §Perf iteration M1.
    xh = shard(xh, "data", None, "model", None)
    dt = shard(dt, "data", None, "model")
    a_rate = jnp.exp(p["A_log"])[None, None, :]                  # (1,1,NH)
    log_a = -dt * a_rate                                         # (B,S,NH) ≤ 0

    # --- reshape into chunks ---
    xc = xh.reshape(b, nc, ck, nh, hd)
    bc_ = bmat.reshape(b, nc, ck, n)
    cc_ = cmat.reshape(b, nc, ck, n)
    dtc = dt.reshape(b, nc, ck, nh)
    lac = log_a.reshape(b, nc, ck, nh)
    cum = jnp.cumsum(lac, axis=2)                                # (B,NC,CK,NH)

    # --- intra-chunk (dual quadratic form) ---
    # decay(i<-j) = exp(cum_i - cum_j), causal i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # (B,NC,i,j,NH)
    ii = jnp.arange(ck)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    # mask BEFORE exp: the non-causal side has diff > 0 and exp overflows,
    # poisoning gradients through jnp.where.
    decay = jnp.exp(jnp.where(causal, diff, -jnp.inf))
    decay = shard(decay, "data", None, None, None, "model")
    # dig_ssm_ssd: the SSD dual-form contractions are digital by design
    # (data-dependent, not weight-stationary — they never map onto a CIM
    # array); the scope declares them to the jaxpr ledger audit.
    with jax.named_scope("dig_ssm_ssd"):
        cb = jnp.einsum("bgin,bgjn->bgij", cc_, bc_)             # (B,NC,i,j)
    att = cb[..., None] * decay * dtc[:, :, None, :, :]          # (B,NC,i,j,NH)
    with jax.named_scope("dig_ssm_ssd"):
        y_intra = jnp.einsum("bgijh,bgjhp->bgihp", att, xc)

    # --- chunk summaries and inter-chunk scan ---
    # state contribution of chunk g: Σ_j exp(cum_last - cum_j)·dt_j·B_j⊗x_j
    tail = jnp.exp(cum[:, :, -1:, :] - cum) * dtc                # (B,NC,CK,NH)
    with jax.named_scope("dig_ssm_ssd"):
        chunk_state = jnp.einsum("bgjh,bgjn,bgjhp->bghnp", tail, bc_, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (B,NC,NH)

    def scan_fn(h_prev, inp):
        cs, cd = inp                                             # state, decay
        h_new = cd[..., None, None] * h_prev + cs
        return h_new, h_prev                                     # emit state *before* chunk

    h0 = jnp.zeros((b, nh, n, hd), jnp.float32)
    _, h_before = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_before = jnp.moveaxis(h_before, 0, 1)                      # (B,NC,NH,N,P)
    h_before = shard(h_before, "data", None, "model", None, None)

    # --- inter-chunk output: y_j += C_j · exp(cum_j) · H_before ---
    inter_w = jnp.exp(cum)                                       # (B,NC,CK,NH)
    with jax.named_scope("dig_ssm_ssd"):
        y_inter = jnp.einsum(
            "bgin,bgih,bghnp->bgihp", cc_, inter_w, h_before
        )

    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(b, s, di).astype(u.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    y = shard(y, "data", None, "model")
    return dense(p["out_proj"], y, cfg.cim, "ssm")


def _recurrence_step(p, cfg: ArchConfig, kernel, a_rate,
                     h, win, x_t, b_t, c_t, dt_t):
    """One SSD time step from (h, conv window) — the single source of the
    per-token update shared by decode and prefill, so the bucketed
    prefill's bitwise-equivalence contract can't drift from the decode
    math. x_t (B, di), b_t/c_t (B, N), dt_t (B, NH).
    Returns (h_new, win_new, y) with y (B, NH, P) pre-gate/-norm."""
    b = x_t.shape[0]
    nh, hd = cfg.ssm_heads, cfg.ssm_headdim
    win_full = jnp.concatenate([win, x_t[:, None, :].astype(win.dtype)],
                               axis=1)
    xc = jnp.sum(win_full * kernel[None, :, :], axis=1)          # (B, di)
    xh = jax.nn.silu(xc).reshape(b, nh, hd).astype(jnp.float32)
    a = jnp.exp(-dt_t * a_rate)                                  # (B, NH)
    with jax.named_scope("dig_ssm_ssd"):
        dbx = jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t, xh)
    h_new = a[..., None, None] * h + dbx
    with jax.named_scope("dig_ssm_ssd"):
        y = jnp.einsum("bn,bhnp->bhp", c_t, h_new)
    y = y + p["D"][None, :, None] * xh
    return h_new, win_full[:, 1:, :], y


def ssm_decode(
    p, u: jax.Array, cfg: ArchConfig, state: dict
) -> Tuple[jax.Array, dict]:
    """One-token recurrence. u: (B,1,D); state: {"h","conv"}."""
    b, s, d = u.shape
    assert s == 1
    di = cfg.d_inner

    z, x, bmat, cmat, dt = _project(p, u, cfg)
    kernel = p["conv"].astype(jnp.float32)
    a_rate = jnp.exp(p["A_log"])[None, :]
    h_new, new_conv, y = _recurrence_step(
        p, cfg, kernel, a_rate, state["h"], state["conv"],
        x[:, 0, :], bmat[:, 0, :], cmat[:, 0, :], dt[:, 0, :])
    y = y.reshape(b, 1, di).astype(u.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y, cfg.cim, "ssm")
    return out, {"h": h_new, "conv": new_conv}


def ssm_prefill(
    p, u: jax.Array, cfg: ArchConfig, state: dict, length: jax.Array
) -> Tuple[jax.Array, dict]:
    """Chunked prefill: the decode recurrence over u (B, S, D) in one pass.

    Unlike ``ssm_train`` (the chunked-parallel SSD dual form, whose f32
    accumulation order drifts from the recurrence), this scans
    ``_recurrence_step`` — the same op sequence as ``ssm_decode`` — so a
    bucketed prefill reproduces the token-by-token cache trajectory.
    ``length`` (B,) counts valid leading tokens per lane; steps at
    ``t >= length`` freeze both the SSM state and the conv window bitwise.
    """
    b, s, d = u.shape
    di = cfg.d_inner
    z, x, bmat, cmat, dt = _project(p, u, cfg)
    kernel = p["conv"].astype(jnp.float32)
    a_rate = jnp.exp(p["A_log"])[None, :]
    valid = jnp.arange(s)[None, :] < length[:, None]             # (B, S)

    def step(carry, t_in):
        h, win = carry
        x_t, b_t, c_t, dt_t, v_t = t_in
        h_new, win_new, y = _recurrence_step(
            p, cfg, kernel, a_rate, h, win, x_t, b_t, c_t, dt_t)
        h_new = jnp.where(v_t[:, None, None, None], h_new, h)
        win_new = jnp.where(v_t[:, None, None], win_new, win)
        return (h_new, win_new), y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(bmat, 1, 0),
          jnp.moveaxis(cmat, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(valid, 1, 0))
    (h_last, win_last), y_seq = jax.lax.scan(
        step, (state["h"], state["conv"]), xs)
    y = jnp.moveaxis(y_seq, 0, 1).reshape(b, s, di).astype(u.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y, cfg.cim, "ssm")
    return out, {"h": h_last, "conv": win_last}
