"""Mixture-of-Experts FFN: top-k token-choice routing, fixed expert
capacity, expert-parallel execution.

Dispatch/combine use *grouped* routing (GShard-style): tokens are grouped
by data shard and each group scatters into its own capacity slice, so under
shard_map every scatter/gather is device-local — GSPMD cannot partition a
scatter with computed indices and otherwise falls back to full replication
(a 60 GiB/device buffer for grok-314B at 1M tokens; see DESIGN.md).  The
global buffer layout is (E, G·C_g, D) with the capacity dim sharded over the
data axes; expert weights are EP-sharded over "model" when E divides it and
intra-expert TP-sharded otherwise, and the expert einsums stay in GSPMD.

Arctic-style ``moe_dense_residual`` runs a dense MLP in parallel and sums.
Aux load-balancing loss follows Switch/Shazeer: E·Σ_e f_e·p_e.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.core import costs
from repro.kernels.ops import site_marker
from repro.models.layers import dense, init_dense, init_mlp, mlp
from repro.parallel.sharding import axis_divides, batch_axes, get_mesh, shard

__all__ = ["init_moe", "moe"]


def init_moe(key, cfg: ArchConfig, dtype):
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f * 2 * cfg.n_layers)
    p = {
        "router": init_dense(ks[0], d, e, dtype=jnp.float32),
        "experts": {
            "wi": (scale_in * jax.random.normal(ks[1], (e, d, f))).astype(dtype),
            "wo": (scale_out * jax.random.normal(ks[2], (e, f, d))).astype(dtype),
        },
    }
    if cfg.gated_mlp:
        p["experts"]["wg"] = (
            scale_in * jax.random.normal(ks[3], (e, d, f))
        ).astype(dtype)
    if cfg.moe_dense_residual:
        p["dense_mlp"] = init_mlp(ks[4], d, cfg.d_ff, cfg, dtype)
    return p


def _dispatch_local(xf, expert_idx, valid, e: int, cap: int):
    """Group-local dispatch: (T, D), (T, k) -> buf (E, cap, D), slot, keep.

    ``valid`` (T,) excludes tokens from routing entirely: they consume no
    expert capacity and combine to zero. Chunked prefill routes right-padded
    bucket rows through here; without the mask, padding would steal capacity
    from real tokens and make bucketed prefill diverge from the
    token-by-token path whenever an expert is near its cap.

    Residual caveat (fixed-capacity MoE is shape-dependent by design): a
    bucketed chunk pools one cap over all its real tokens, while the
    token-by-token path gets a fresh per-call cap, so the two prefill modes
    are only equivalent while no expert overflows its cap in either mode —
    true for near-uniform routing at cap >= ceil(T·k/e·1.25), but a
    heavily collapsed router can drop late prompt tokens in bucketed mode
    that per-token dispatch would keep (and the single-token decode path
    has no ``valid`` mask, so placeholder lanes there still take slots).
    """
    t, d = xf.shape
    k = expert_idx.shape[-1]
    flat_expert = expert_idx.reshape(-1)                          # (T*k,)
    flat_valid = jnp.repeat(valid, k)                             # (T*k,)
    eq = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)          # (T*k, E)
    eq = eq * flat_valid[:, None].astype(jnp.int32)
    pos_in_e = (jnp.cumsum(eq, axis=0) - eq) * eq
    position = jnp.sum(pos_in_e, axis=-1)                         # (T*k,)
    keep = (position < cap) & flat_valid
    slot = flat_expert * cap + jnp.minimum(position, cap - 1)
    src = jnp.repeat(xf, k, axis=0)
    buf = jnp.zeros((e * cap, d), xf.dtype).at[slot].add(
        jnp.where(keep[:, None], src, 0.0))
    return buf.reshape(e, cap, d), slot, keep


def _combine_local(out_buf, slot, keep, gates, k: int):
    """Group-local combine: buf (E, cap, D) -> tokens (T, D)."""
    e, cap, d = out_buf.shape
    flat = out_buf.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], flat[slot], 0.0)          # (T*k, D)
    weighted = gathered * gates.reshape(-1, 1).astype(out_buf.dtype)
    t = slot.shape[0] // k
    return jnp.sum(weighted.reshape(t, k, d), axis=1)


def moe(p, x: jax.Array, cfg: ArchConfig,
        valid: "jax.Array | None" = None) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balance loss scalar).

    ``valid`` (B, S) optionally marks real tokens; invalid ones are kept
    out of expert capacity (see ``_dispatch_local``). ``None`` means all."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)
    vf = (jnp.ones((t,), bool) if valid is None
          else valid.reshape(t).astype(bool))

    # --- routing (always f32 for numerics) ---
    logits = dense(p["router"], xf.astype(jnp.float32), cfg.cim,
                   "moe_router")
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # aux loss: fraction of tokens per expert × mean router prob per expert
    one_hot_top1 = jax.nn.one_hot(expert_idx[:, 0], e)
    f_e = jnp.mean(one_hot_top1, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)

    mesh = get_mesh()
    ba = batch_axes(mesh) if mesh is not None else None
    nb = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    grouped = mesh is not None and t % nb == 0 and (t // nb) >= 1

    if grouped:
        cap = max(4, int(math.ceil(t / nb * k / e * cfg.capacity_factor)))
        disp = shard_map(
            lambda xf_l, ei_l, vf_l: _dispatch_local(xf_l, ei_l, vf_l, e, cap),
            mesh=mesh,
            in_specs=(P(ba, None), P(ba, None), P(ba)),
            out_specs=(P(None, ba, None), P(ba), P(ba)),
        )
        buf, slot, keep = disp(xf, expert_idx, vf)
    else:
        cap = max(4, int(math.ceil(t * k / e * cfg.capacity_factor)))
        buf, slot, keep = _dispatch_local(xf, expert_idx, vf, e, cap)

    # EP over "model" when E divides it; otherwise intra-expert TP with the
    # hidden dim over "model" (grok: 8 experts @ 16-way TP).
    ep = axis_divides(e, "model")
    buf = shard(buf, "model" if ep else None, "data", None)

    # --- expert computation: (E, C, D) @ (E, D, F) --- (GSPMD)
    # Cost accounting: the ledger records the *logical* routed compute —
    # T·k token-assignments through each of the expert matmuls — not the
    # fixed-capacity (E, cap) dispatch buffer, whose padded rows would
    # never be mapped onto an analog array (and whose size is a serving
    # heuristic, not model structure). The expert einsums themselves stay
    # digital batched GEMMs (the router is the CIM-simulated matmul here);
    # their *pricing* still follows the "moe_expert" site design.
    f = cfg.expert_d_ff
    eff = cfg.cim.for_site("moe_expert")
    costs.record_matmul("moe_expert", t * k, d, f, eff)
    if cfg.gated_mlp:
        costs.record_matmul("moe_expert", t * k, d, f, eff)
    costs.record_matmul("moe_expert", t * k, f, d, eff)
    wi = p["experts"]["wi"].astype(x.dtype)
    wo = p["experts"]["wo"].astype(x.dtype)
    # Audit markers mirror the record_matmul contracts above: the expert
    # einsums run at buffer shapes but account (and are audited) at the
    # logical routed-compute shapes.
    m_in = site_marker("moe_expert", t * k, d, f)
    m_out = site_marker("moe_expert", t * k, f, d)
    with jax.named_scope(m_in), jax.named_scope("cim_values"):
        h = jnp.einsum("ecd,edf->ecf", buf, wi)
    if cfg.gated_mlp:
        wg = p["experts"]["wg"].astype(x.dtype)
        with jax.named_scope(m_in), jax.named_scope("cim_values"):
            hg = jnp.einsum("ecd,edf->ecf", buf, wg)
        h = jax.nn.silu(hg) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "model", "data", None) if ep else shard(
        h, None, "data", "model")
    with jax.named_scope(m_out), jax.named_scope("cim_values"):
        out_buf = jnp.einsum("ecf,efd->ecd", h, wo)
    out_buf = shard(out_buf, "model" if ep else None, "data", None)

    # --- combine ---
    if grouped:
        comb = shard_map(
            lambda ob_l, sl_l, kp_l, g_l: _combine_local(ob_l, sl_l, kp_l, g_l, k),
            mesh=mesh,
            in_specs=(P(None, ba, None), P(ba), P(ba), P(ba, None)),
            out_specs=P(ba, None),
        )
        out = comb(out_buf, slot, keep, gate_vals)
    else:
        out = _combine_local(out_buf, slot, keep, gate_vals, k)
    out = out.reshape(b, s, d)

    if cfg.moe_dense_residual:
        out = out + mlp(p["dense_mlp"], x, cfg)
    return out, aux
