"""Shared transformer layers: norms, rotary embeddings, GQA attention
(full + sliding window, train and cached-decode paths), and MLPs.

All projection matmuls route through ``repro.kernels.ops.cim_matmul`` with a
**site** label (``core.cim_config.SITES``), so the paper's GR-CIM numerics
can be switched on — and mixed per site — via ``CIMConfig.site_overrides``
(legacy family-level ``apply_to`` still works), and so the cost/trace
subsystem (``core.costs``) can account every matmul from its real call site.
Functional style: ``init_*`` builds param pytrees, ``apply_*`` consumes them.
Compute dtype follows the inputs; softmax/normalization accumulate in f32.
"""
from __future__ import annotations

import math

import numpy as np
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.cim_config import CIMConfig
from repro.kernels.ops import cim_matmul
from repro.parallel.sharding import shard

__all__ = [
    "init_dense",
    "dense",
    "init_rmsnorm",
    "rmsnorm",
    "rope",
    "init_attention",
    "attention",
    "init_mlp",
    "mlp",
]

_NEG_INF = -1e30


# ------------------------------------------------------------------ basics
def init_dense(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None,
               bias: bool = False):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (scale * jax.random.normal(key, (d_in, d_out))).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, cim: Optional[CIMConfig] = None, site: str = "mlp",
          logical_n: Optional[int] = None):
    """x @ W (+ b), through the CIM simulation resolved for this site
    (``cim.for_site(site)``; None or a site resolving to "off" is exact).
    ``logical_n`` overrides the ledger-recorded output width (LM head)."""
    y = cim_matmul(x, p["w"].astype(x.dtype), cim, site=site,
                   logical_n=logical_n)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_rmsnorm(d: int, dtype):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    return ((x32 / rms) * p["g"].astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding. x: (B, S, H, Dh); positions: (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )
    return out.astype(x.dtype)


# ------------------------------------------------------------------ attention
def _attend_chunked(q, kk, vv, pos_q, pos_k, cfg: ArchConfig, local: bool):
    """Query-chunked masked attention against full keys.

    q: (B, Sq, H, Dh); kk/vv: (B, Sk, KV, Dh); positions give causality.
    Bounds score materialization to (B, H, ck, Sk) per chunk.
    """
    b, sq, h, dh = q.shape
    kv = kk.shape[2]
    groups = h // kv

    def attend(q_c, pos_c):
        c = q_c.shape[1]
        qg = q_c.reshape(b, c, kv, groups, dh)
        # dig_attn: score/value contractions are digital by design (the
        # paper maps only weight-stationary projections onto CIM arrays) —
        # the scope declares them to the jaxpr ledger audit.
        with jax.named_scope("dig_attn"):
            scores = jnp.einsum("bskgd,btkd->bkgst", qg, kk,
                                preferred_element_type=jnp.float32)
        scores = scores / math.sqrt(dh)
        mask = _attn_mask(pos_c, pos_k, cfg.window, local)      # (B, C, Sk)
        scores = jnp.where(mask[:, None, None, :, :], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        with jax.named_scope("dig_attn"):
            o = jnp.einsum("bkgst,btkd->bskgd", probs, vv)
        return o.reshape(b, c, h, dh)

    ck = cfg.attn_chunk or sq
    while sq % ck:
        ck //= 2
    if ck >= sq:
        return attend(q, pos_q)
    outs = [attend(q[:, i * ck:(i + 1) * ck], pos_q[:, i * ck:(i + 1) * ck])
            for i in range(sq // ck)]
    return jnp.concatenate(outs, axis=1)


def _train_attention(q, k, v, positions, cfg: ArchConfig, local: bool):
    """Full-sequence attention with explicit sequence parallelism.

    When a mesh is active (and shapes divide), runs under shard_map with
    queries sharded over "model" (each query block is independent given all
    keys) and K/V replicated across "model" — no GSPMD guessing, no
    involuntary remat in the backward. K/V gradients psum over "model"
    automatically. Falls back to single-device chunked attention otherwise.
    """
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import batch_axes, get_mesh

    b, s, h, dh = q.shape
    mesh = get_mesh()
    if mesh is not None:
        ba = batch_axes(mesh)
        nb = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
        nm = mesh.shape["model"]
        if b % nb == 0 and s % nm == 0 and (s // nm) >= 1:
            qspec = P(ba, "model", None, None)
            kvspec = P(ba, None, None, None)
            pq = P(ba, "model")
            pk = P(ba, None)

            def local_fn(q_l, k_l, v_l, posq_l, posk_l):
                return _attend_chunked(q_l, k_l, v_l, posq_l, posk_l,
                                       cfg, local)

            from repro.compat import shard_map

            return shard_map(
                local_fn, mesh=mesh,
                in_specs=(qspec, kvspec, kvspec, pq, pk),
                out_specs=qspec,
            )(q, k, v, positions, positions)
    return _attend_chunked(q, k, v, positions, positions, cfg, local)


def init_attention(key, cfg: ArchConfig, dtype):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d, h * dh, dtype, bias=cfg.qkv_bias),
        "wk": init_dense(ks[1], d, kv * dh, dtype, bias=cfg.qkv_bias),
        "wv": init_dense(ks[2], d, kv * dh, dtype, bias=cfg.qkv_bias),
        "wo": init_dense(ks[3], h * dh, d, dtype,
                         scale=1.0 / math.sqrt(h * dh * 2 * cfg.n_layers)),
    }


def _attn_mask(q_pos, k_pos, window: int, local: bool):
    """(.., S_q, S_k) boolean mask: causal, optionally banded."""
    causal = q_pos[..., :, None] >= k_pos[..., None, :]
    if local:
        causal &= q_pos[..., :, None] - k_pos[..., None, :] < window
    return causal


def _chunk_prefill_attention(q, k, v, x, cache, cache_index, chunk_lengths,
                             cfg: ArchConfig, local: bool):
    """Multi-token cached attention for bucketed prefill.

    Writes the chunk's K/V at per-lane offsets ``cache_index + t`` and
    attends each query causally, in one dispatch. Steps with
    ``t >= chunk_lengths[b]`` (right padding, lanes not being prefilled)
    are redirected out of bounds and dropped by the scatter, so those
    lanes' caches pass through bitwise unchanged — no host-side merge.

    For ring-buffer (local) caches only the last ``min(len, ring)`` valid
    steps may write (earlier steps share ring residues with later ones and
    scatter order over duplicates is unspecified); scores are taken against
    the *pre-write* ring plus the in-flight chunk keys, because a chunk
    longer than the window overwrites ring entries early queries still see.

    Scoring goes through ``_attend_chunked`` with everything encoded as
    positions, so the causal/window mask logic is shared with the train
    path and score materialization stays bounded by ``cfg.attn_chunk``:
    keys that must be invisible (never-written ring slots, padded chunk
    steps) simply carry a position greater than every valid query's.
    """
    b, s = q.shape[0], q.shape[1]
    s_ctx = cache["k"].shape[1]
    idx = jnp.broadcast_to(jnp.asarray(cache_index), (b,))
    lengths = jnp.broadcast_to(jnp.asarray(chunk_lengths), (b,))
    steps = jnp.arange(s)
    q_pos = idx[:, None] + steps[None, :]                        # (B, S)
    step_valid = steps[None, :] < lengths[:, None]               # (B, S)

    kc = k.astype(cache["k"].dtype)
    vc = v.astype(cache["v"].dtype)
    if local:
        writer = step_valid & (steps[None, :] >= (lengths - s_ctx)[:, None])
        tgt = jnp.where(writer, jnp.mod(q_pos, s_ctx), s_ctx)
    else:
        tgt = jnp.where(step_valid, q_pos, s_ctx)
    upd = jax.vmap(lambda c, u, t: c.at[t].set(u, mode="drop"))
    new_cache = {"k": upd(cache["k"], kc, tgt), "v": upd(cache["v"], vc, tgt)}

    if local:
        # positions held by the pre-chunk ring (last write was idx - 1);
        # never-written slots resolve negative — push them past every query
        # so the causal mask drops them. Padded chunk keys keep their
        # over-length positions, which already exceed every valid query's;
        # padded queries see garbage but their outputs never reach a cache.
        slot = jnp.arange(s_ctx)[None, :]
        last_old = idx - 1
        age = jnp.mod(jnp.mod(last_old, s_ctx)[:, None] - slot, s_ctx)
        k_pos_old = last_old[:, None] - age                      # (B, s_ctx)
        k_pos_old = jnp.where(k_pos_old >= 0, k_pos_old, q_pos[:, -1:] + 1)
        keys = jnp.concatenate([cache["k"], kc], axis=1).astype(x.dtype)
        vals = jnp.concatenate([cache["v"], vc], axis=1).astype(x.dtype)
        pos_k = jnp.concatenate([k_pos_old, q_pos], axis=1)
    else:
        # linear cache slot positions are their indices: slots above each
        # query's position (later chunk steps, dropped padding, stale tail)
        # are causally invisible by construction
        keys = new_cache["k"].astype(x.dtype)
        vals = new_cache["v"].astype(x.dtype)
        pos_k = jnp.broadcast_to(jnp.arange(s_ctx)[None, :], (b, s_ctx))

    out = _attend_chunked(q, keys, vals, q_pos, pos_k, cfg, local)
    return out, new_cache


def attention(
    p,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    local: bool,
    positions: jax.Array,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    chunk_lengths: Optional[jax.Array] = None,
):
    """GQA attention.

    Train path: ``cache is None`` — full (B, S, S) masked attention.
    Decode path: ``cache`` = {"k","v"): (B, S_ctx, KV, Dh)} ring/linear
    buffer; ``cache_index`` (scalar) is the write position. Returns
    (out, new_cache).
    Chunked-prefill path: ``cache`` plus ``chunk_lengths`` (B,) — S prompt
    tokens are written at per-lane offsets ``cache_index + t`` and attended
    causally in one pass; steps at ``t >= chunk_lengths`` (bucket padding,
    untouched lanes) never reach the cache.
    """
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    groups = h // kv
    cim = cfg.cim

    q = dense(p["wq"], x, cim, "attn_qkv").reshape(b, s, h, dh)
    k = dense(p["wk"], x, cim, "attn_qkv").reshape(b, s, kv, dh)
    v = dense(p["wv"], x, cim, "attn_qkv").reshape(b, s, kv, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = _train_attention(q, k, v, positions, cfg, local)
        new_cache = None
    elif chunk_lengths is not None:
        out, new_cache = _chunk_prefill_attention(
            q, k, v, x, cache, cache_index, chunk_lengths, cfg, local)
    else:
        # single-token decode: s == 1, write into the cache then attend.
        # ``cache_index`` may be a scalar or a per-sequence (B,) vector
        # (continuous batching: slots at different generation lengths).
        assert s == 1
        s_ctx = cache["k"].shape[1]
        idx = jnp.broadcast_to(jnp.asarray(cache_index), (b,))     # (B,)
        if local:
            write_at = jnp.mod(idx, s_ctx)  # ring buffer
        else:
            write_at = idx
        upd = jax.vmap(
            lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0)))
        kk = upd(cache["k"], k.astype(cache["k"].dtype), write_at)
        vv = upd(cache["v"], v.astype(cache["v"].dtype), write_at)
        new_cache = {"k": kk, "v": vv}
        qg = q.reshape(b, 1, kv, groups, dh)
        with jax.named_scope("dig_attn"):
            scores = jnp.einsum("bskgd,btkd->bkgst", qg, kk.astype(x.dtype),
                                preferred_element_type=jnp.float32)
        scores = scores / math.sqrt(dh)
        # positions of cache slots, per sequence
        slot = jnp.arange(s_ctx)[None, :]                           # (1,S)
        if local:
            age = jnp.mod(write_at[:, None] - slot, s_ctx)
            k_pos = idx[:, None] - age
            valid = (k_pos >= 0) & (k_pos >= (idx - cfg.window + 1)[:, None])
        else:
            valid = slot <= idx[:, None]                            # (B,S)
        scores = jnp.where(valid[:, None, None, None, :], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        with jax.named_scope("dig_attn"):
            out = jnp.einsum("bkgst,btkd->bskgd", probs, vv.astype(x.dtype))

    out = out.reshape(b, s, h * dh)
    return dense(p["wo"], out, cim, "attn_o"), new_cache


# ------------------------------------------------------------------ MLP
def init_mlp(key, d: int, f: int, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "wi": init_dense(ks[0], d, f, dtype),
        "wo": init_dense(ks[1], f, d, dtype,
                         scale=1.0 / math.sqrt(f * 2 * cfg.n_layers)),
    }
    if cfg.gated_mlp:
        p["wg"] = init_dense(ks[2], d, f, dtype)
    return p


def mlp(p, x, cfg: ArchConfig):
    cim = cfg.cim
    hidden = dense(p["wi"], x, cim, "mlp")
    if cfg.gated_mlp:
        hidden = jax.nn.silu(dense(p["wg"], x, cim, "mlp")) * hidden
    else:
        hidden = jax.nn.gelu(hidden)
    hidden = shard(hidden, "data", None, "model")
    return dense(p["wo"], hidden, cim, "mlp")
