"""Version-divergent JAX APIs, resolved in one place.

The repo pins no exact JAX version: CI and the paper experiments run the
0.4.x LTS line while TPU pods track current releases. Every API whose
name or signature moved between those lines is wrapped here so the rest
of the codebase imports `repro.compat` instead of branching inline.

Shimmed surfaces
----------------
``pallas_tpu_compiler_params(...)``
    ``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` (<= 0.4.x /
    early 0.5.x) to ``CompilerParams`` (newer). Returns an instance of
    whichever class exists, or ``None`` when neither does (pure-interpret
    environments) so callers can omit the kwarg.

``make_mesh(axis_shapes, axis_names)``
    ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
    ``jax.make_mesh``) only exist on newer JAX. On those versions we pass
    explicit ``Auto`` axis types (the repo never uses ``Explicit``
    sharding); on older versions the kwarg is dropped — ``Auto`` is the
    only behaviour 0.4.x has, so the semantics are identical.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

__all__ = [
    "pallas_tpu_compiler_params",
    "make_mesh",
    "mesh_axis_types",
    "shard_map",
]


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """``jax.shard_map`` (new name) or ``jax.experimental.shard_map`` (old)."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def pallas_tpu_compiler_params(**kwargs):
    """Build TPU Pallas compiler params across the rename, or None."""
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pragma: no cover - pallas not bundled
        return None
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:  # pragma: no cover - very old pallas
        return None
    return cls(**kwargs)


def mesh_axis_types(n: int) -> Optional[tuple]:
    """``(AxisType.Auto,) * n`` where AxisType exists, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types on JAX versions that have them."""
    types = mesh_axis_types(len(tuple(axis_names)))
    if types is not None:
        return jax.make_mesh(axis_shapes, axis_names, axis_types=types)
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names)
    # pragma: no cover — pre-0.4.35 fallback
    from jax.experimental import mesh_utils

    devices = mesh_utils.create_device_mesh(tuple(axis_shapes))
    return jax.sharding.Mesh(devices, tuple(axis_names))
