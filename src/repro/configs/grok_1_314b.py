"""xAI Grok-1 314B MoE. [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    top_k=2,
    moe_d_ff=32768,
    source="hf:xai-org/grok-1",
    notes="8 experts top-2",
))
