"""The paper's own evaluation point: an edge-scale LM with the GR-CIM
matmul path enabled (FP6_E3M2 activations, FP4_E2M1 weights, N_R=32,
row normalization, ENOB from the data-invariant upper bound)."""
from repro.configs.base import ArchConfig, register
from repro.core.cim_config import CIMConfig
from repro.core.formats import FP4_E2M1, FP6_E3M2

CONFIG = register(ArchConfig(
    name="paper-cim-120m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=32000,
    cim=CIMConfig(
        mode="grmac",
        granularity="row",
        fmt_x=FP6_E3M2,
        fmt_w=FP4_E2M1,
        n_r=32,
    ),
    dtype="float32",
    source="this paper (§III), edge deployment scale",
))
