from repro.configs.base import ArchConfig, get_config, list_configs

__all__ = ["ArchConfig", "get_config", "list_configs"]
