"""Mamba2-1.3B — attention-free SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("ssm",),
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    tie_embeddings=True,
    subquadratic=True,
    source="arXiv:2405.21060",
    notes="SSD chunked algorithm, chunk=256",
))
