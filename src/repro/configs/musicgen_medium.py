"""MusicGen-medium — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Modality frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model); the EnCodec encoder and the
4-codebook interleaving live outside the backbone.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_head=64,
    d_ff=6144,
    vocab_size=2048,
    gated_mlp=False,
    input_mode="embeddings",
    source="arXiv:2306.05284",
))
