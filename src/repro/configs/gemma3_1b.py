"""Gemma-3 1B: 5:1 local:global attention, 128k-class context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab_size=262144,
    block_pattern=("local",) * 5 + ("attn",),
    window=512,
    tie_embeddings=True,
    # long_500k decode is runnable: 5/6 of layers keep a 512-token window
    # cache; the rare global layers are O(S) per decoded token.
    subquadratic=True,
    source="hf:google/gemma-3-1b-pt",
    notes="5:1 local:global, MQA (kv=1)",
))
