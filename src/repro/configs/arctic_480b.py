"""Snowflake Arctic 480B — dense-MoE hybrid. [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    moe_dense_residual=True,   # dense residual MLP in parallel with the MoE
    source="hf:Snowflake/snowflake-arctic-base",
    notes="128 experts top-2 + dense residual path",
))
