"""ArchConfig — one dataclass covering all ten assigned architecture families.

A model is a stack of *blocks* drawn cyclically from ``block_pattern``:
    "attn"   full causal self-attention (GQA/MQA)
    "local"  sliding-window causal self-attention
    "rglru"  RG-LRU recurrent block (RecurrentGemma / Griffin)
    "ssm"    Mamba2 SSD block
Each block is followed by an FFN (dense MLP, or MoE when ``n_experts > 0``).
SSM blocks are self-contained (no separate FFN), matching Mamba2.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.cim_config import CIMConfig

__all__ = ["ArchConfig", "register", "get_config", "list_configs"]

_REGISTRY: dict = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...] = ("attn",)
    window: int = 4096                # sliding-window size for "local" blocks
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 2
    moe_d_ff: int = 0                 # expert hidden dim (0 -> d_ff)
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- RG-LRU (RecurrentGemma) ---
    lru_width: int = 0                # 0 -> d_model
    # --- misc ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    gated_mlp: bool = True
    tie_embeddings: bool = False
    input_mode: str = "tokens"        # tokens | embeddings (modality-stub)
    subquadratic: bool = False        # can run long_500k
    notes: str = ""
    source: str = ""
    cim: CIMConfig = dataclasses.field(default_factory=CIMConfig)
    dtype: str = "bfloat16"
    remat: bool = True
    # remat policy: "full" recomputes everything in backward (min memory);
    # "dots" saves matmul outputs and recomputes elementwise only
    # (jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    remat_policy: str = "full"
    # scan over layer super-blocks (compact HLO; cost_analysis counts scan
    # bodies once -> the roofline pass sets scan_layers=False)
    scan_layers: bool = True
    # query-chunked (flash-style) attention: bounds score materialization
    # to (B, H, attn_chunk, S); None -> one full S x S einsum
    attn_chunk: int = 1024

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so logits/embeddings shard over
        any mesh axis (50280 -> 50432 etc.). Pad logits are masked to -inf
        in the loss/decode paths; labels never index them."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:          # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def rnn_width(self) -> int:
        return self.lru_width or self.d_model

    def blocks(self) -> Tuple[str, ...]:
        """The full per-layer block-kind sequence."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def pattern_period(self) -> int:
        return len(self.block_pattern)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        period = self.pattern_period()
        return self.replace(
            name=self.name + "-reduced",
            n_layers=max(period, 2 if period == 1 else period),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=32,
            d_ff=256,
            moe_d_ff=128 if self.is_moe else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            window=64,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32,
            ssm_chunk=16,
            lru_width=128 if self.family == "hybrid" else 0,
            dtype="float32",
            remat=False,
            attn_chunk=16,
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d  # embed
        if not self.tie_embeddings:
            total += d * v
        for kind in self.blocks():
            if kind in ("attn", "local"):
                total += d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
                total += self.n_heads * self.d_head * d
                ffn = True
            elif kind == "rglru":
                w = self.rnn_width
                total += 2 * d * w + 2 * w * w // 1 + w * d  # in/out + gates
                ffn = True
            elif kind == "ssm":
                di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * di + 2 * ns + nh) + di * d
                ffn = False
            else:
                raise ValueError(kind)
            if ffn:
                if self.is_moe:
                    e_ff = self.expert_d_ff
                    total += self.n_experts * (3 if self.gated_mlp else 2) * d * e_ff
                    total += d * self.n_experts  # router
                    if self.moe_dense_residual:
                        total += (3 if self.gated_mlp else 2) * d * f
                else:
                    total += (3 if self.gated_mlp else 2) * d * f
        return total


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # Importing the modules triggers register() calls.
    from repro.configs import (  # noqa: F401
        arctic_480b,
        chameleon_34b,
        gemma3_1b,
        granite_8b,
        grok_1_314b,
        mamba2_1p3b,
        musicgen_medium,
        paper_cim,
        qwen2_1p5b,
        recurrentgemma_9b,
        stablelm_3b,
    )
