"""RecurrentGemma-9B — RG-LRU + local attention, 2:1 (Griffin).
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=4096,
    subquadratic=True,
    source="arXiv:2402.19427",
    notes="RG-LRU recurrence with fixed-size state; local window 2048",
))
