"""Chameleon-34B — early-fusion VLM over interleaved text + VQ image tokens.
[arXiv:2405.09818; unverified]

VQ image tokens live in the shared 65536 vocabulary, so the backbone
consumes plain token ids; the VQ-GAN tokenizer is the (stubbed) frontend.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22016,
    vocab_size=65536,
    source="arXiv:2405.09818",
))
