"""Monte-Carlo ADC resolution (ENOB) requirement solver (paper §IV-A).

The ADC must keep the noise it introduces at least 6 dB below the output-
referred quantization noise floor of the input format:

    SNR_ADC >= SQNR_out + 6 dB
    <=>  P_adc_noise <= P_qnoise_out / 10^0.6

Only *input* quantization noise is considered (Fig. 10 caption): weights are
treated as exact signal (they are sampled on their format grid).  The ADC
noise, referred to the dot-product output, is

    P_adc = (Δ² / 12) · E[scale²]

with the digital renormalization ``scale`` of the architecture (constant
``n_r`` for the conventional INT-MAC; the data-dependent ``Σ 2^E · 2^-e_max``
for the GR-MAC).  The required resolution follows the paper's definition

    ENOB = log2(V_FS / Δ),   V_FS = 2   (bipolar full scale)

and is therefore fractional-valued.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from .distributions import Distribution, max_entropy, uniform
from .formats import FP4_E2M1, FPFormat, IntFormat, quantize_any
from .mac import gr_mac_row, gr_mac_unit, int_mac

__all__ = ["EnobResult", "required_enob", "solve_required_enob",
           "narrowest_uniform", "ARCHS"]

ARCHS = ("conv", "gr_row", "gr_unit")
_MARGIN_DB = 6.0


@dataclasses.dataclass
class EnobResult:
    enob: float             # required ADC resolution (fractional bits)
    sqnr_out_db: float      # output-referred SQNR from input quantization
    sig_power: float        # P(z_ref)
    qnoise_power: float     # P(z_q - z_ref)
    mean_scale_sq: float    # E[scale²] of the renormalization factor
    n_eff_mean: Optional[float] = None  # GR only


def required_enob(
    key: jax.Array,
    arch: str,
    dist_x: Distribution,
    fmt_x: Union[FPFormat, IntFormat],
    n_r: int = 32,
    fmt_w: FPFormat = FP4_E2M1,
    dist_w: Optional[Distribution] = None,
    n_cols: int = 1 << 14,
    margin_db: float = _MARGIN_DB,
) -> EnobResult:
    """Solve the minimum ADC ENOB for one (architecture, input condition).

    ``arch``: "conv" (FP->INT direct accumulation), "gr_row", or "gr_unit".
    GR architectures require ``fmt_x`` to be an FPFormat; with an IntFormat
    input there is no exponent to range on and "conv" semantics apply
    (INT-normalization reuses gr semantics through the *weight* format — pass
    arch="gr_unit" with an IntFormat input for that case: inputs then carry a
    single exponent bin).
    """
    kx, kw = jax.random.split(key)
    shape = (n_cols, n_r)
    x = dist_x(kx, shape)
    if dist_w is None:
        dist_w = max_entropy(fmt_w)
    w_q = dist_w(kw, shape)  # already on the weight grid for max-entropy

    x_q = quantize_any(x, fmt_x)

    # Output-referred input-quantization noise (the budget reference).
    z_ref = jnp.sum(x * w_q, axis=-1)
    z_q = jnp.sum(x_q * w_q, axis=-1)
    p_sig = jnp.mean(jnp.square(z_ref))
    p_qn = jnp.mean(jnp.square(z_q - z_ref))

    # Renormalization-scale statistics of the architecture (ENOB-independent;
    # pass a dummy ENOB, we only need `scale`).
    n_eff_mean = None
    if arch == "conv" or isinstance(fmt_x, IntFormat):
        out = int_mac(x_q, w_q, 16.0)
        mean_scale_sq = jnp.mean(jnp.square(out.scale))
    elif arch == "gr_row":
        out = gr_mac_row(x_q, w_q, fmt_x, 16.0)
        mean_scale_sq = jnp.mean(jnp.square(out.scale))
        n_eff_mean = float(jnp.mean(out.n_eff))
    elif arch == "gr_unit":
        out = gr_mac_unit(x_q, w_q, fmt_x, fmt_w, 16.0)
        mean_scale_sq = jnp.mean(jnp.square(out.scale))
        n_eff_mean = float(jnp.mean(out.n_eff))
    else:
        raise ValueError(f"unknown arch {arch!r}")

    # Δ² / 12 · E[scale²] <= P_qn / 10^(margin/10)
    p_allowed = p_qn / 10.0 ** (margin_db / 10.0)
    delta = jnp.sqrt(12.0 * p_allowed / jnp.maximum(mean_scale_sq, 1e-30))
    enob = jnp.log2(2.0 / delta)

    return EnobResult(
        enob=float(enob),
        sqnr_out_db=float(10.0 * jnp.log10(p_sig / jnp.maximum(p_qn, 1e-30))),
        sig_power=float(p_sig),
        qnoise_power=float(p_qn),
        mean_scale_sq=float(mean_scale_sq),
        n_eff_mean=n_eff_mean,
    )


def narrowest_uniform(fmt: Union[FPFormat, IntFormat]) -> Distribution:
    """Uniform input at the narrowest valid bounds of the format (§IV-B):
    twice the minimum normal value for FP, full scale for INT. This is the
    paper's reference input condition for dimensioning converters — the
    worst case the static ENOB spec must be robust to."""
    if isinstance(fmt, IntFormat):
        return uniform(1.0)
    return uniform(min(1.0, 2.0 * fmt.min_normal))


@functools.lru_cache(maxsize=8192)
def solve_required_enob(
    arch: str,
    fmt_x: Union[FPFormat, IntFormat],
    n_r: int = 32,
    fmt_w: FPFormat = FP4_E2M1,
    n_cols: int = 1 << 14,
    seed: int = 0,
    margin_db: float = _MARGIN_DB,
) -> EnobResult:
    """Memoized ``required_enob`` at the paper's reference input condition.

    Keyed on the FULL candidate tuple — (arch, fmt_x, n_r, fmt_w) plus the
    sampling configuration (n_cols, seed, margin) — so the combinatorial
    per-site DSE sweep (``core.dse.explore_pareto``: formats × n_r ×
    granularity × every ledger site) pays each distinct Monte-Carlo solve
    exactly once per process. The input distribution is always
    ``narrowest_uniform(fmt_x)``; call ``required_enob`` directly for
    custom distributions (it stays un-memoized: ``Distribution`` closures
    are not hashable cache keys)."""
    key = jax.random.PRNGKey(seed)
    return required_enob(key, arch, narrowest_uniform(fmt_x), fmt_x,
                         n_r=n_r, fmt_w=fmt_w, n_cols=n_cols,
                         margin_db=margin_db)
