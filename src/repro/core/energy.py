"""Energy models for CIM components and full arrays (paper §IV-B, Appendix).

Component models (Table II) with 28 nm @ 0.9 V parameters (Table III), all in
femtojoules.  Array-level roll-ups follow §III-C's normalization-granularity
descriptions (which logic exists, and what it is amortized over):

  conventional  ADC + wide DAC + cell switching over the FP->INT width
  gr_row        narrow DAC, +1 gain switch, per-row exponent decoder (/N_C),
                one exponent adder tree per array (/N_R·N_C),
                output multiplier per column (/N_R)
  gr_unit       narrow DAC + narrow divider, per-cell exponent adder+decoder
                (unamortized), adder tree and multiplier per column (/N_R)
  gr_int        integer inputs, static weight exponents: decoder does not
                toggle; precomputed column sums; multiplier per column only

Each MAC is two Ops.  Energy-per-op = total MVM energy / (2 · N_R · N_C).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

from .formats import FPFormat, IntFormat

__all__ = [
    "TechParams",
    "CimDesign",
    "adc_energy_fj",
    "dac_energy_fj",
    "adder_tree_fa_count",
    "energy_per_op_fj",
    "EnergyBreakdown",
]


@dataclasses.dataclass(frozen=True)
class TechParams:
    """Cost-model parameters @ 0.9 V, 28 nm (Table III)."""

    c_gate_ff: float = 0.7   # fF — reference NAND2/NOR2 gate capacitance
    k1_ff: float = 100.0     # fF — ADC linear term
    k2_ff: float = 1e-3      # fF — ADC 4^ENOB term (1 aF)
    k3_ff: float = 50.0      # fF — DAC switching cap per bit
    vdd: float = 0.9         # V
    # Activity factor of the one-hot exponent adder tree ("low-activity
    # one-hot inputs", §III-B2). Not specified numerically in the paper;
    # exposed as a calibration knob, see DESIGN.md.
    tree_activity: float = 0.5

    @property
    def vdd_sq(self) -> float:
        return self.vdd * self.vdd

    @property
    def e_fa_fj(self) -> float:
        """Full-adder energy: 6·C_gate·VDD²."""
        return 6.0 * self.c_gate_ff * self.vdd_sq

    def n_cross(self) -> float:
        """Boundary of thermal-noise-limited ADC scaling (~10 b for Table III).

        Solves k1·N = k2·4^N for N (where the exponential term overtakes the
        linear baseline), by bisection.
        """
        lo, hi = 1.0, 20.0
        f = lambda n: self.k2_ff * 4.0**n - self.k1_ff * n
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            if f(mid) > 0:
                hi = mid
            else:
                lo = mid
        return 0.5 * (lo + hi)


def adc_energy_fj(enob: float, p: TechParams = TechParams()) -> float:
    """(k1·ENOB + k2·4^ENOB)·VDD² — per conversion."""
    return (p.k1_ff * enob + p.k2_ff * 4.0**enob) * p.vdd_sq


def dac_energy_fj(res_bits: float, p: TechParams = TechParams()) -> float:
    """k3·DAC_res·VDD² — per conversion."""
    return p.k3_ff * res_bits * p.vdd_sq


def mult_energy_fj(n_a: int, n_b: Optional[int] = None, p: TechParams = TechParams()) -> float:
    """N-bit multiplier: (1.5·C_gate·VDD² + E_FA)·N² (generalized to N_a·N_b)."""
    n_b = n_a if n_b is None else n_b
    return (1.5 * p.c_gate_ff * p.vdd_sq + p.e_fa_fj) * n_a * n_b


def decoder_energy_fj(n_in: int, n_out: int, p: TechParams = TechParams()) -> float:
    """(0.5·N_in + N_out + 1)·C_gate·VDD²."""
    return (0.5 * n_in + n_out + 1) * p.c_gate_ff * p.vdd_sq


def adder_tree_fa_count(n_inputs: int, in_width: int) -> int:
    """FA count of a binary reduction tree over ``n_inputs`` words.

    Level k merges pairs with operand width in_width + k - 1.
    """
    total = 0
    n = n_inputs
    w = in_width
    while n > 1:
        pairs = n // 2
        total += pairs * w
        n = n - pairs
        w += 1
    return total


def cell_switch_energy_fj(n_sw: int, n_r: int, n_c: int, p: TechParams = TechParams()) -> float:
    """0.5·C_gate·VDD²·N_SW·N_R·N_C — whole-array bitline switching per MVM."""
    return 0.5 * p.c_gate_ff * p.vdd_sq * n_sw * n_r * n_c


@dataclasses.dataclass(frozen=True)
class CimDesign:
    """One point in the design space."""

    arch: str                               # conv | gr_row | gr_unit | gr_int
    fmt_x: Union[FPFormat, IntFormat]
    fmt_w: FPFormat
    enob: float                             # from core.adc.required_enob
    n_r: int = 32
    n_c: int = 32

    @property
    def x_is_int(self) -> bool:
        return isinstance(self.fmt_x, IntFormat)

    def int_width(self, fmt: FPFormat) -> int:
        """FP->INT aligned width: mantissa (incl. implicit) + shift range."""
        return (fmt.n_man + 1) + (fmt.e_max - 1)

    @property
    def dac_res(self) -> int:
        if self.x_is_int:
            return self.fmt_x.bits
        if self.arch == "conv":
            return self.int_width(self.fmt_x)
        return self.fmt_x.n_man + 1  # normalized mantissa only

    @property
    def gain_range_bits(self) -> int:
        """Octaves spanned by the gain-ranging coupling ladder."""
        if self.arch in ("conv",):
            return 0
        bits = 0
        if not self.x_is_int and self.arch in ("gr_row", "gr_unit"):
            bits += self.fmt_x.e_max - 1
        if self.arch in ("gr_unit", "gr_int"):
            bits += self.fmt_w.e_max - 1
        return bits


@dataclasses.dataclass
class EnergyBreakdown:
    adc: float
    dac: float
    cells: float
    logic: float  # exponent adders/decoders/trees/output multipliers

    @property
    def total(self) -> float:
        return self.adc + self.dac + self.cells + self.logic

    def as_dict(self) -> dict:
        return {
            "adc": self.adc,
            "dac": self.dac,
            "cells": self.cells,
            "logic": self.logic,
            "total": self.total,
        }


def energy_per_op_fj(d: CimDesign, p: TechParams = TechParams()) -> EnergyBreakdown:
    """Per-Op (MAC = 2 Ops) energy of one CIM array design point."""
    n_r, n_c = d.n_r, d.n_c
    ops = 2.0 * n_r * n_c
    log2nr = max(1, math.ceil(math.log2(n_r)))

    e_adc = n_c * adc_energy_fj(d.enob, p)
    e_dac = n_r * dac_energy_fj(d.dac_res, p)
    e_logic = 0.0

    if d.arch == "conv":
        n_sw = d.int_width(d.fmt_w)
        e_cells = cell_switch_energy_fj(n_sw, n_r, n_c, p)

    elif d.arch == "gr_row":
        # Weights stored pre-shifted (storage overhead, §III-C2): divider
        # spans the aligned weight width; +1 switch for the gain stage.
        n_sw = d.int_width(d.fmt_w) + 1
        e_cells = cell_switch_energy_fj(n_sw, n_r, n_c, p)
        ne_x = d.fmt_x.n_exp
        e_maxx = d.fmt_x.e_max
        # One decoder per row, serving N_C cells.
        e_logic += n_r * decoder_energy_fj(ne_x, e_maxx, p)
        # One exponent adder tree per array over N_R one-hot words.
        fa = adder_tree_fa_count(n_r, e_maxx)
        e_logic += fa * p.e_fa_fj * p.tree_activity
        # Output normalization multiplier per column: ADC code × exp-sum.
        sum_w = e_maxx + log2nr
        e_logic += n_c * mult_energy_fj(math.ceil(d.enob), sum_w, p)

    elif d.arch == "gr_unit":
        n_sw = (d.fmt_w.n_man + 1) + 1
        e_cells = cell_switch_energy_fj(n_sw, n_r, n_c, p)
        ne_x = 0 if d.x_is_int else d.fmt_x.n_exp
        ne_w = d.fmt_w.n_exp
        e_maxx = 0 if d.x_is_int else d.fmt_x.e_max
        e_maxw = d.fmt_w.e_max
        esum_w = max(ne_x, ne_w) + 1
        onehot_w = max(1, (e_maxx - 1) + (e_maxw - 1) + 1)
        # Per-cell exponent adder (E_x + E_W) and gain decoder — unamortized.
        e_logic += n_r * n_c * (esum_w * p.e_fa_fj)
        e_logic += n_r * n_c * decoder_energy_fj(esum_w, onehot_w, p)
        # Adder tree per column.
        fa = adder_tree_fa_count(n_r, onehot_w)
        e_logic += n_c * fa * p.e_fa_fj * p.tree_activity
        sum_w = onehot_w + log2nr
        e_logic += n_c * mult_energy_fj(math.ceil(d.enob), sum_w, p)

    elif d.arch == "gr_int":
        # Integer inputs, FP weights with *static* exponents: decoders and
        # column exponent sums are compile-time constants (no toggling).
        n_sw = (d.fmt_w.n_man + 1) + 1
        e_cells = cell_switch_energy_fj(n_sw, n_r, n_c, p)
        e_maxw = d.fmt_w.e_max
        sum_w = (e_maxw - 1) + 1 + log2nr
        e_logic += n_c * mult_energy_fj(math.ceil(d.enob), sum_w, p)

    else:
        raise ValueError(f"unknown arch {d.arch!r}")

    return EnergyBreakdown(
        adc=e_adc / ops, dac=e_dac / ops, cells=e_cells / ops, logic=e_logic / ops
    )


def global_norm_energy_per_op_fj(
    width_bits: int, shift_range: int, n_r: int, n_c: int, p: TechParams = TechParams()
) -> float:
    """Overhead of a global (block-wise) normalization wrapper (§II-B2).

    Models a max-exponent comparator tree over the input block plus a
    ``width_bits``-wide barrel shifter (log2(shift_range) mux stages) per
    input. Runs once per MVM over N_R inputs; amortized per Op. This is a
    derived extension (the paper only includes CIM-array energy for FP8*).
    """
    stages = max(1, math.ceil(math.log2(max(2, shift_range))))
    shifter = width_bits * stages * 0.5 * p.c_gate_ff * p.vdd_sq
    cmp_tree = adder_tree_fa_count(n_r, stages) * p.e_fa_fj
    return (n_r * shifter + cmp_tree) / (2.0 * n_r * n_c)
