"""CIMConfig — how the GR-CIM technique is applied inside a model.

This is the knob exposed in every architecture config (``cim`` field) and
consumed by ``repro.kernels.ops.cim_matmul`` and the model layers.

Modes
-----
off        plain bf16/f32 matmuls (digital baseline).
fakequant  inputs/weights quantized to the CIM formats with straight-through
           gradients (QAT); accumulation is exact. Trains models that will
           tolerate CIM numerics.
grmac      full GR-MAC signal-chain simulation: per-K-block mantissa
           accumulation, ADC quantization at the configured ENOB, digital
           renormalization. Deployment-faithful inference numerics.

``granularity`` selects the paper's normalization domain (§III-C); ``n_r``
is the CIM array depth, i.e. the K-block over which one analog accumulation
+ one ADC conversion happens.

``backend`` picks the grmac execution backend (see ``kernels.dispatch``):
"auto" (shape-aware plan: batched-einsum XLA path at small/decode M, fused
tiled path at large/training M, Pallas kernel on TPU — optionally refined
by the ``REPRO_GRMAC_AUTOTUNE=1`` measured plan cache), "xla", "tiled",
"pallas", "pallas_interpret" (debug), or "ref" (jnp oracle). Threaded
through ``cim_matmul`` and overridable per call site
(ServeConfig.cim_backend, TrainConfig.cim_backend). ``tile_m``/``tile_n``
pin the tiled/Pallas tile sizes (None lets the plan decide).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .formats import FP4_E2M1, FP6_E3M2, FPFormat

__all__ = ["CIMConfig"]


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    mode: str = "off"                  # off | fakequant | grmac
    granularity: str = "row"           # row | unit
    fmt_x: FPFormat = FP6_E3M2
    fmt_w: FPFormat = FP4_E2M1
    n_r: int = 32                      # CIM array rows == matmul K-block
    enob: Optional[float] = None       # None -> solve from core.adc defaults
    backend: str = "auto"     # auto | xla | tiled | pallas | pallas_interpret | ref
    tile_m: Optional[int] = None       # None -> planned (tiled/pallas only)
    tile_n: Optional[int] = None       # None -> planned; 0 -> no N-tiling
    # Per-tensor pre-scale: activations are scaled into [-1, 1] by their
    # running absmax before quantization (standard PTQ practice); the scale
    # is folded back after the MAC.
    dynamic_prescale: bool = True
    # Apply the CIM path to these matmul families.
    apply_to: tuple = ("ffn", "qkvo", "expert", "head")

    @property
    def enabled(self) -> bool:
        return self.mode != "off"

    def resolved_enob(self) -> float:
        if self.enob is not None:
            return self.enob
        # Data-invariant upper bound (paper contribution C2): the uniform
        # distribution upper-bounds the GR-MAC ADC requirement, so a static
        # spec is safe for any input data. Solved offline (see
        # benchmarks/fig10_enob_dr.py); 8 bits covers FP6_E3M2 / FP4 weights
        # at N_R = 32 with margin.
        return 8.0

    def with_mode(self, mode: str) -> "CIMConfig":
        return dataclasses.replace(self, mode=mode)

    def with_backend(self, backend: str) -> "CIMConfig":
        return dataclasses.replace(self, backend=backend)

    def with_tiles(self, tile_m: Optional[int],
                   tile_n: Optional[int] = None) -> "CIMConfig":
        return dataclasses.replace(self, tile_m=tile_m, tile_n=tile_n)
