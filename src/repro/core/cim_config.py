"""CIMConfig — how the GR-CIM technique is applied inside a model.

This is the knob exposed in every architecture config (``cim`` field) and
consumed by ``repro.kernels.ops.cim_matmul`` and the model layers.

Modes
-----
off        plain bf16/f32 matmuls (digital baseline).
fakequant  inputs/weights quantized to the CIM formats with straight-through
           gradients (QAT); accumulation is exact. Trains models that will
           tolerate CIM numerics.
grmac      full GR-MAC signal-chain simulation: per-K-block mantissa
           accumulation, ADC quantization at the configured ENOB, digital
           renormalization. Deployment-faithful inference numerics.

``granularity`` selects the paper's normalization domain (§III-C): "row",
"unit", or "conv" (the conventional CIM, no gain ranging); ``n_r`` is the
CIM array depth, i.e. the K-block over which one analog accumulation + one
ADC conversion happens.

Per-site policy
---------------
Every projection matmul in the models carries a **site** label (see
``SITES``): attention QKV vs output projections, the dense MLP, the MoE
router and expert stacks, the SSM and RG-LRU projection heads, and the LM
head. Which design runs at a site is resolved by ``for_site``:

1. ``site_overrides`` — a tuple of ``(site, override)`` pairs where the
   override is either the string ``"off"`` (digital at that site) or a
   ``SiteDesign`` whose non-None fields replace the base design. This is
   the first-class mixed-deployment knob: e.g. a conventional-CIM LM head
   next to a gr-row FFN is
   ``cim.override_site("head", SiteDesign(granularity="conv"))``.
2. otherwise the legacy coarse switch: the site's *family* (``qkvo`` /
   ``ffn`` / ``expert`` / ``head``) must be in ``apply_to``. ``apply_to``
   is therefore a degenerate case of the override map (family-level
   on/off with the one base design).

``for_site`` returns a plain resolved ``CIMConfig`` (no overrides left),
which is what ``cim_matmul`` executes and what ``core.costs`` records into
the ``CostLedger`` — so energy pricing and numerics can never disagree
about which design a site runs.

``backend`` picks the grmac execution backend (see ``kernels.dispatch``):
"auto" (shape-aware plan: batched-einsum XLA path at small/decode M, fused
tiled path at large/training M, Pallas kernel on TPU — optionally refined
by the ``REPRO_GRMAC_AUTOTUNE=1`` measured plan cache), "xla", "tiled",
"pallas", "pallas_interpret" (debug), or "ref" (jnp oracle). Threaded
through ``cim_matmul`` and overridable per call site
(ServeConfig.cim_backend, TrainConfig.cim_backend). ``tile_m``/``tile_n``
pin the tiled/Pallas tile sizes (None lets the plan decide).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

from .formats import FP4_E2M1, FP6_E3M2, FPFormat, IntFormat, parse_format

__all__ = ["CIMConfig", "SiteDesign", "SITES", "site_family"]


# Canonical matmul-site labels threaded from the model layers into
# ``cim_matmul`` (and from there into core.costs.CostLedger). The legacy
# family names ("qkvo", "ffn", "expert", "head") are also accepted as sites
# for external callers of ``dense``.
SITES = (
    "attn_qkv",     # attention wq/wk/wv projections
    "attn_o",       # attention output projection
    "mlp",          # dense MLP (wi / wg / wo), incl. MoE dense residual
    "moe_router",   # MoE router logits
    "moe_expert",   # MoE expert stacks (wi / wg / wo)
    "rglru",        # RG-LRU in/gate/out projections
    "ssm",          # Mamba2 in/bc/dt/out projections
    "head",         # LM head (tied or untied)
)

_SITE_FAMILY = {
    "attn_qkv": "qkvo",
    "attn_o": "qkvo",
    "mlp": "ffn",
    "moe_router": "expert",
    "moe_expert": "expert",
    "rglru": "qkvo",
    "ssm": "qkvo",
    "head": "head",
    # legacy family names double as sites (identity mapping)
    "qkvo": "qkvo",
    "ffn": "ffn",
    "expert": "expert",
}


def site_family(site: str) -> str:
    """The coarse ``apply_to`` family a site belongs to."""
    return _SITE_FAMILY.get(site, site)


@dataclasses.dataclass(frozen=True)
class SiteDesign:
    """A per-site design override: non-None fields replace the base
    ``CIMConfig`` fields at that site (see ``CIMConfig.for_site``).

    ``fmt_x`` may be an ``IntFormat``: the DSE sweep
    (``core.dse.explore_pareto``) prices INT inputs through the ``gr_int``
    energy arch, and the ENOB solver treats them as a single exponent bin."""

    mode: Optional[str] = None          # off | fakequant | grmac
    granularity: Optional[str] = None   # row | unit | conv
    fmt_x: Optional[Union[FPFormat, IntFormat]] = None
    fmt_w: Optional[FPFormat] = None
    n_r: Optional[int] = None
    enob: Optional[float] = None

    def as_kwargs(self) -> dict:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
                if getattr(self, f.name) is not None}

    # ------------------------------------------------------ serialization
    def as_dict(self) -> dict:
        """JSON-able dump (formats by name); inverse of ``from_dict``."""
        out = self.as_kwargs()
        for k in ("fmt_x", "fmt_w"):
            if k in out:
                out[k] = out[k].name
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "SiteDesign":
        kw = dict(d)
        for k in ("fmt_x", "fmt_w"):
            if isinstance(kw.get(k), str):
                kw[k] = parse_format(kw[k])
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    mode: str = "off"                  # off | fakequant | grmac
    granularity: str = "row"           # row | unit | conv
    fmt_x: Union[FPFormat, IntFormat] = FP6_E3M2
    fmt_w: FPFormat = FP4_E2M1
    n_r: int = 32                      # CIM array rows == matmul K-block
    enob: Optional[float] = None       # None -> solve from core.adc defaults
    backend: str = "auto"     # auto | xla | tiled | pallas | pallas_interpret | ref
    tile_m: Optional[int] = None       # None -> planned (tiled/pallas only)
    tile_n: Optional[int] = None       # None -> planned; 0 -> no N-tiling
    # Per-tensor pre-scale: activations are scaled into [-1, 1] by their
    # running absmax before quantization (standard PTQ practice); the scale
    # is folded back after the MAC.
    dynamic_prescale: bool = True
    # Legacy coarse policy: apply the CIM path to these matmul families.
    # Consulted only for sites without an entry in ``site_overrides``.
    apply_to: tuple = ("ffn", "qkvo", "expert", "head")
    # First-class per-site policy: ((site, "off" | SiteDesign), ...).
    # Resolved by ``for_site``; wins over ``apply_to``.
    site_overrides: Tuple[Tuple[str, Union[str, SiteDesign]], ...] = ()

    @property
    def enabled(self) -> bool:
        return self.mode != "off" or any(
            ov != "off" and ov.mode not in (None, "off")
            for _, ov in self.site_overrides)

    def resolved_enob(self) -> float:
        if self.enob is not None:
            return self.enob
        # Data-invariant upper bound (paper contribution C2): the uniform
        # distribution upper-bounds the GR-MAC ADC requirement, so a static
        # spec is safe for any input data. Solved offline (see
        # benchmarks/fig10_enob_dr.py); 8 bits covers FP6_E3M2 / FP4 weights
        # at N_R = 32 with margin.
        return 8.0

    # ------------------------------------------------------------ policy
    def for_site(self, site: Optional[str]) -> "CIMConfig":
        """Resolve the design that runs at ``site``.

        Returns a plain CIMConfig with ``site_overrides`` cleared: an
        ``"off"`` override (or a family absent from ``apply_to``) resolves
        to ``mode="off"``; a ``SiteDesign`` override replaces its non-None
        fields. ``site=None`` means "already resolved" (external callers
        of ``cim_matmul`` that pass a finished design).
        """
        if site is None:
            return self
        return _resolve_site(self, site)

    def override_site(
        self, site: str, design: Union[str, SiteDesign]
    ) -> "CIMConfig":
        """Return a config with ``site`` overridden (replacing any existing
        entry for the same site). ``design`` is ``"off"`` or a SiteDesign.
        ``site`` must be a canonical site label (``SITES``) or a legacy
        family name — a typo'd site would otherwise silently never match
        any model call site (and the deployment would not be the one the
        user believes they configured)."""
        if site not in _SITE_FAMILY:
            raise ValueError(
                f"unknown site {site!r}: expected one of {SITES} "
                "or a legacy family name ('qkvo'/'ffn'/'expert'/'head')")
        if design != "off" and not isinstance(design, SiteDesign):
            raise TypeError(f"override must be 'off' or SiteDesign, "
                            f"got {design!r}")
        kept = tuple((s, d) for s, d in self.site_overrides if s != site)
        return dataclasses.replace(
            self, site_overrides=kept + ((site, design),))

    def with_site_overrides(self, overrides) -> "CIMConfig":
        """Apply a whole ``{site: "off" | SiteDesign}`` mapping (or an
        iterable of pairs) at once — the shape ``core.dse.explore_pareto``
        emits as its ready-to-apply chosen frontier. Later entries replace
        earlier ones for the same site; sites are applied in the mapping's
        iteration order."""
        items = overrides.items() if hasattr(overrides, "items") \
            else overrides
        cfg = self
        for site, design in items:
            cfg = cfg.override_site(site, design)
        return cfg

    # ------------------------------------------------------------ sugar
    def with_mode(self, mode: str) -> "CIMConfig":
        return dataclasses.replace(self, mode=mode)

    def with_backend(self, backend: str) -> "CIMConfig":
        return dataclasses.replace(self, backend=backend)

    def with_tiles(self, tile_m: Optional[int],
                   tile_n: Optional[int] = None) -> "CIMConfig":
        return dataclasses.replace(self, tile_m=tile_m, tile_n=tile_n)


@functools.lru_cache(maxsize=4096)
def _resolve_site(cfg: CIMConfig, site: str) -> CIMConfig:
    base = (dataclasses.replace(cfg, site_overrides=())
            if cfg.site_overrides else cfg)
    ov = next((d for s, d in cfg.site_overrides if s == site), None)
    if ov is not None:
        if ov == "off":
            return dataclasses.replace(base, mode="off")
        return dataclasses.replace(base, **ov.as_kwargs())
    if site_family(site) in cfg.apply_to:
        return base
    return dataclasses.replace(base, mode="off")
