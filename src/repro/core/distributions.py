"""Input-data distributions used for ADC requirement analysis (paper §IV-A).

Three distributions define the hardware requirements:

i)   Uniform            — the standard INT-CIM baseline; lower-bounds the
                          conventional ADC requirement, upper-bounds GR-MAC's.
ii)  Maximum entropy    — the floating-point analogue of the uniform baseline:
                          uniformly randomized format bits (format-dependent).
iii) Gaussian + outliers — empirical LLM-activation stress test: a narrow
                          Gaussian core plus rare uniform high-magnitude
                          outliers (ε = 0.01, k = 50 relative to the core 3σ).

All samplers return values in [-1, 1] (full scale). ``scale`` shrinks the
distribution into the lower part of the range — used to model inputs that
occupy only the "narrowest valid bounds" of a wide-DR format (§IV-B).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .formats import FPFormat, max_entropy_sample

__all__ = [
    "Distribution",
    "uniform",
    "gaussian_clipped",
    "gaussian_outliers",
    "max_entropy",
    "DISTRIBUTIONS",
]


@dataclasses.dataclass(frozen=True)
class Distribution:
    """A named sampler: (key, shape) -> array in [-1, 1]."""

    name: str
    sample: Callable[[jax.Array, tuple], jax.Array]

    def __call__(self, key: jax.Array, shape: tuple) -> jax.Array:
        return self.sample(key, shape)


def uniform(scale: float = 1.0) -> Distribution:
    def _s(key, shape):
        return jax.random.uniform(key, shape, minval=-scale, maxval=scale)

    return Distribution(f"uniform(x{scale:g})", _s)


def gaussian_clipped(n_sigma: float = 4.0, scale: float = 1.0) -> Distribution:
    """Zero-mean normal clipped to ±n_sigma, full scale at the clip point.

    This is the Fig. 4 illustration condition (normal clipped to 4σ).
    """
    sigma = scale / n_sigma

    def _s(key, shape):
        x = sigma * jax.random.normal(key, shape)
        return jnp.clip(x, -scale, scale)

    return Distribution(f"gauss_clip{n_sigma:g}s", _s)


def gaussian_outliers(eps: float = 0.01, k: float = 50.0, scale: float = 1.0) -> Distribution:
    """Gaussian core + uniform high-magnitude outliers (§IV-A iii).

    The outlier magnitude is ``k`` relative to the core's 3σ; full scale is
    set so the largest outliers just avoid clipping: sigma = scale / (3 k).
    With probability ``eps`` a sample is drawn uniformly over the full range.
    """
    sigma = scale / (3.0 * k)

    def _s(key, shape):
        kc, ko, kb = jax.random.split(key, 3)
        core = jnp.clip(sigma * jax.random.normal(kc, shape), -scale, scale)
        outl = jax.random.uniform(ko, shape, minval=-scale, maxval=scale)
        take = jax.random.bernoulli(kb, eps, shape)
        return jnp.where(take, outl, core)

    return Distribution(f"gauss+outliers(e{eps:g},k{k:g})", _s)


def max_entropy(fmt: FPFormat, scale: float = 1.0) -> Distribution:
    """Uniformly randomized bits of ``fmt`` — the quantizer prior (§IV-A ii)."""

    def _s(key, shape):
        return scale * max_entropy_sample(key, shape, fmt)

    return Distribution(f"maxent({fmt.name})", _s)


def DISTRIBUTIONS(fmt: Optional[FPFormat] = None) -> dict:
    """The paper's three evaluation distributions, keyed by short name."""
    d = {
        "uniform": uniform(),
        "gauss_outliers": gaussian_outliers(),
    }
    if fmt is not None:
        d["max_entropy"] = max_entropy(fmt)
    return d
