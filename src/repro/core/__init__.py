"""Core reproduction of the paper's contribution: formats, MAC signal chains,
ADC requirement analysis, energy models, and design-space exploration."""
from .cim_config import CIMConfig
from .formats import (
    FP4_E2M1,
    FP6_E2M3,
    FP6_E3M2,
    FP8_E4M3,
    FPFormat,
    IntFormat,
    decompose,
    int_quantize,
    quantize,
    sqnr_db,
)
from .mac import adc_quantize, gr_mac_row, gr_mac_unit, int_mac, n_eff

__all__ = [
    "CIMConfig",
    "FPFormat",
    "IntFormat",
    "FP4_E2M1",
    "FP6_E2M3",
    "FP6_E3M2",
    "FP8_E4M3",
    "quantize",
    "decompose",
    "int_quantize",
    "sqnr_db",
    "adc_quantize",
    "int_mac",
    "gr_mac_row",
    "gr_mac_unit",
    "n_eff",
]
