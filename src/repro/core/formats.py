"""Floating-point and integer codecs on the normalized interval [-1, +1].

The paper (§III-A) treats all signals as dimensionless quantities normalized to
the unit interval.  A floating-point scalar is

    x = (-1)^S * M * 2^(E - E_max)

with the *effective* significand ``M``:

    normals     M = 1.m / 2  in [0.5, 1)
    subnormals  M = 0.m / 2  in [0.0, 0.5)     (stored exponent code 0)

and the *effective* exponent ``E = max(1, E_stored)``, ``E_stored`` occupying
``n_exp`` bits so ``E in [1, e_max]`` with ``e_max = 2**n_exp - 1``.

Everything here is pure jnp and jit/vmap-safe; shapes are preserved.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "FPFormat",
    "IntFormat",
    "FP4_E2M1",
    "FP6_E2M3",
    "FP6_E3M2",
    "FP8_E4M3",
    "quantize",
    "decompose",
    "compose",
    "int_quantize",
    "quantize_any",
    "parse_format",
    "sqnr_db",
    "measured_sqnr_db",
]


@dataclasses.dataclass(frozen=True)
class FPFormat:
    """A sign + ``n_exp`` exponent bits + ``n_man`` stored mantissa bits format."""

    n_exp: int
    n_man: int  # stored mantissa bits, excluding the implicit leading bit

    @property
    def e_max(self) -> int:
        return 2**self.n_exp - 1

    @property
    def bits(self) -> int:
        return 1 + self.n_exp + self.n_man

    @property
    def name(self) -> str:
        return f"FP{self.bits}_E{self.n_exp}M{self.n_man}"

    @property
    def max_value(self) -> float:
        """Largest representable magnitude (< 1)."""
        return 1.0 - 2.0 ** (-self.n_man - 1)

    @property
    def min_normal(self) -> float:
        """Smallest normal magnitude: M=0.5 at E=1."""
        return 2.0 ** (-self.e_max)

    @property
    def min_subnormal(self) -> float:
        """Smallest nonzero magnitude (one subnormal LSB)."""
        return 2.0 ** (-self.n_man - self.e_max)

    @property
    def dr_db(self) -> float:
        """Dynamic range in dB: full-scale over *twice the minimum normal*.

        The paper dimensions converters for "a uniform input scaled to its
        narrowest valid bounds ... twice the minimum normal value" (§IV-B).
        """
        import math

        return 20.0 * math.log10(1.0 / (2.0 * self.min_normal))

    # --- dataclass sugar -------------------------------------------------
    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.name


@dataclasses.dataclass(frozen=True)
class IntFormat:
    """Signed mid-tread uniform quantizer with ``bits`` total bits on [-1, 1]."""

    bits: int

    @property
    def levels(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def name(self) -> str:
        return f"INT{self.bits}"


FP4_E2M1 = FPFormat(2, 1)
FP6_E2M3 = FPFormat(2, 3)
FP6_E3M2 = FPFormat(3, 2)
FP8_E4M3 = FPFormat(4, 3)


def parse_format(name: str):
    """Inverse of ``FPFormat.name`` / ``IntFormat.name``: ``"FP6_E3M2"`` or
    ``"INT8"`` back to the format object (used to round-trip per-site
    designs through JSON records)."""
    if name.startswith("INT"):
        return IntFormat(int(name[3:]))
    try:
        spec = name.split("_", 1)[1]          # "E3M2"
        n_exp, n_man = spec[1:].split("M")
        fmt = FPFormat(int(n_exp), int(n_man))
    except (IndexError, ValueError) as e:
        raise ValueError(f"unparseable format name {name!r}") from e
    if fmt.name != name:
        raise ValueError(f"format name {name!r} does not round-trip "
                         f"(parsed as {fmt.name})")
    return fmt

_TINY = 1e-30


def pow2i(e: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Exact 2**e for integer-valued ``e``.

    jnp.exp2 is NOT bit-exact on all backends (XLA CPU lowers it through
    exp(x·ln2), off by 1 ULP for some integers), which breaks grid-exact
    quantization. ldexp constructs the exponent field directly.
    """
    return jnp.ldexp(jnp.ones((), dtype), e.astype(jnp.int32))


def _eff_exponent(a: jax.Array, fmt: FPFormat) -> jax.Array:
    """Effective exponent E in [1, e_max] for magnitudes ``a``.

    Uses frexp (a = f * 2**e, f in [0.5, 1)) so powers of two land exactly.
    """
    _, e = jnp.frexp(jnp.maximum(a, _TINY))
    return jnp.clip(e.astype(jnp.int32) + fmt.e_max, 1, fmt.e_max)


def quantize(x: jax.Array, fmt: FPFormat) -> jax.Array:
    """Round-to-nearest quantization of ``x`` onto the format grid.

    Saturating: |x| > max_value clamps to max_value. Values in [-1, 1] are
    expected; larger values saturate (the format cannot represent them).
    """
    a = jnp.abs(x)
    e = _eff_exponent(a, fmt)
    # LSB at this exponent: grid step for M is 2^-(n_man+1); value step is
    # that times 2^(E - e_max).
    lsb = pow2i(e - fmt.e_max - fmt.n_man - 1, x.dtype)
    q = jnp.round(a / lsb) * lsb
    q = jnp.minimum(q, jnp.asarray(fmt.max_value, x.dtype))
    return jnp.where(x < 0, -q, q)


def decompose(xq: jax.Array, fmt: FPFormat):
    """Split (already quantized) values into (sign, M, E).

    Returns
    -------
    sign : ±1 (0 stays +1 with M=0)
    M    : effective significand in [0, 1);  [0.5, 1) for normals
    E    : effective exponent in [1, e_max] (int32)

    such that  xq == sign * M * 2**(E - e_max).
    """
    a = jnp.abs(xq)
    e = _eff_exponent(a, fmt)
    m = a * pow2i(fmt.e_max - e, xq.dtype)
    sign = jnp.where(xq < 0, -1.0, 1.0).astype(xq.dtype)
    return sign, m, e


def compose(sign: jax.Array, m: jax.Array, e: jax.Array, fmt: FPFormat) -> jax.Array:
    return sign * m * pow2i(e - fmt.e_max, m.dtype)


def int_quantize(x: jax.Array, fmt: IntFormat) -> jax.Array:
    lv = fmt.levels
    q = jnp.round(jnp.clip(x, -1.0, 1.0) * lv) / lv
    return q


def quantize_any(x: jax.Array, fmt) -> jax.Array:
    """Round-to-nearest onto either format family's grid: dispatches to
    ``int_quantize`` for ``IntFormat`` and ``quantize`` for ``FPFormat``
    (the DSE sweeps both; per-site overrides may carry either)."""
    if isinstance(fmt, IntFormat):
        return int_quantize(x, fmt)
    return quantize(x, fmt)


def sqnr_db(fmt: FPFormat) -> float:
    """Theoretical format SQNR (paper §IV-A): 6.02·N_M + 10.79 dB.

    Distribution-independent, provided data stays in range. ``N_M`` here is
    the stored mantissa bit count (the implicit leading bit contributes the
    +10.79 dB offset relative to the integer formula).
    """
    return 6.02 * fmt.n_man + 10.79


def measured_sqnr_db(x: jax.Array, xq: jax.Array) -> jax.Array:
    """Empirical signal-to-quantization-noise ratio in dB."""
    p_sig = jnp.mean(jnp.square(x))
    p_err = jnp.mean(jnp.square(x - xq))
    return 10.0 * jnp.log10(p_sig / jnp.maximum(p_err, _TINY))


@partial(jax.jit, static_argnums=(1, 2))
def max_entropy_sample(key: jax.Array, shape: tuple, fmt: FPFormat) -> jax.Array:
    """Sample the format's maximum-entropy distribution (§IV-A ii).

    Obtained by uniformly randomizing the bits of the format: sign, stored
    exponent code, and stored mantissa are each uniform.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    sign = jnp.where(jax.random.bernoulli(k1, 0.5, shape), 1.0, -1.0)
    e_stored = jax.random.randint(k2, shape, 0, 2**fmt.n_exp)
    m_bits = jax.random.randint(k3, shape, 0, 2**fmt.n_man)
    is_normal = (e_stored > 0).astype(jnp.float32)
    e_eff = jnp.maximum(e_stored, 1)
    m = (is_normal + m_bits.astype(jnp.float32) / 2**fmt.n_man) / 2.0
    return sign * m * pow2i(e_eff - fmt.e_max)
