"""Design-space exploration over (dynamic range, precision) — paper Fig. 12.

Each design point is an input format (``n_exp``, ``n_man``).  Precision
(SQNR) is set by the mantissa; excess dynamic range beyond the minimum needed
for that SQNR is set by the exponent range (``e_max - 1`` octaves).

Per §IV-B, converters are dimensioned to robustly process *a uniform input
scaled to its narrowest valid bounds* (twice the minimum normal value): the
excess DR manifests as a 2^-(e_max-1) amplitude reduction for the
conventional CIM, while the GR-MAC renormalizes it away.  Weights are
FP4_E2M1 max-entropy throughout (information-optimal first-order
approximation of empirical weights).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax

from .adc import required_enob
from .distributions import uniform
from .energy import CimDesign, EnergyBreakdown, TechParams, energy_per_op_fj
from .formats import FP4_E2M1, FPFormat, IntFormat

__all__ = ["DsePoint", "explore", "spec_of_format", "GAIN_RANGE_LIMIT_BITS"]

# Conservative C-2C linearity limit on the coupling-ladder span (§III-D1).
GAIN_RANGE_LIMIT_BITS = 6


@dataclasses.dataclass
class DsePoint:
    fmt_x: FPFormat | IntFormat
    dr_db: float
    sqnr_db: float
    conv: Optional[EnergyBreakdown]      # None when outside conventional reach
    gr: Optional[EnergyBreakdown]        # best GR granularity (None if infeasible)
    gr_arch: Optional[str]
    enob_conv: float
    enob_gr: float


def spec_of_format(fmt: FPFormat | IntFormat) -> tuple[float, float]:
    """(DR_dB, SQNR_dB) coordinates of a format in the design space.

    DR counts total resolvable bits: information bits (mantissa incl. the
    implicit leading one) plus excess-range octaves.  SQNR follows the
    6.02·N_M + 10.79 dB floating-point formula (stored mantissa bits).
    """
    if isinstance(fmt, IntFormat):
        bits = fmt.bits
        return 6.02 * bits, 6.02 * (bits - 1) + 1.76
    dr_bits = (fmt.n_man + 2) + (fmt.e_max - 1)  # sign+implicit+stored + range
    return 6.02 * dr_bits, 6.02 * fmt.n_man + 10.79


def _narrowest_uniform(fmt: FPFormat | IntFormat):
    """Uniform input at the narrowest valid bounds of the format (§IV-B)."""
    if isinstance(fmt, IntFormat):
        return uniform(1.0)
    return uniform(min(1.0, 2.0 * fmt.min_normal))


def evaluate_point(
    key: jax.Array,
    fmt_x: FPFormat | IntFormat,
    fmt_w: FPFormat = FP4_E2M1,
    n_r: int = 32,
    n_c: int = 32,
    p: TechParams = TechParams(),
    n_cols: int = 1 << 13,
) -> DsePoint:
    dist = _narrowest_uniform(fmt_x)
    dr_db, sqnr_db = spec_of_format(fmt_x)

    res_conv = required_enob(key, "conv", dist, fmt_x, n_r=n_r, fmt_w=fmt_w, n_cols=n_cols)
    conv = energy_per_op_fj(
        CimDesign("conv", fmt_x, fmt_w, res_conv.enob, n_r, n_c), p
    )

    best = None
    best_arch = None
    best_enob = float("nan")
    if isinstance(fmt_x, IntFormat):
        cand = ["gr_int"]
    else:
        cand = ["gr_row", "gr_unit"]
    for arch in cand:
        solver_arch = "gr_unit" if arch == "gr_int" else arch
        res = required_enob(key, solver_arch, dist, fmt_x, n_r=n_r, fmt_w=fmt_w, n_cols=n_cols)
        d = CimDesign(arch, fmt_x, fmt_w, res.enob, n_r, n_c)
        if d.gain_range_bits > GAIN_RANGE_LIMIT_BITS:
            continue  # outside the coupling ladder's linear span
        e = energy_per_op_fj(d, p)
        if best is None or e.total < best.total:
            best, best_arch, best_enob = e, arch, res.enob

    return DsePoint(
        fmt_x=fmt_x,
        dr_db=dr_db,
        sqnr_db=sqnr_db,
        conv=conv,
        gr=best,
        gr_arch=best_arch,
        enob_conv=res_conv.enob,
        enob_gr=best_enob,
    )


def explore(
    key: jax.Array,
    n_exps=(0, 1, 2, 3, 4),
    n_mans=(1, 2, 3, 4, 5, 6),
    fmt_w: FPFormat = FP4_E2M1,
    n_r: int = 32,
    n_c: int = 32,
    p: TechParams = TechParams(),
    n_cols: int = 1 << 13,
) -> list[DsePoint]:
    """Sweep the (n_exp × n_man) grid.  n_exp == 0 denotes an INT format of
    equivalent precision (sign + implicit + stored mantissa bits)."""
    pts = []
    for ne in n_exps:
        for nm in n_mans:
            fmt = IntFormat(nm + 2) if ne == 0 else FPFormat(ne, nm)
            key, sub = jax.random.split(key)
            pts.append(evaluate_point(sub, fmt, fmt_w, n_r, n_c, p, n_cols))
    return pts
