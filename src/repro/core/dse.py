"""Design-space exploration over (dynamic range, precision) — paper Fig. 12.

Each design point is an input format (``n_exp``, ``n_man``).  Precision
(SQNR) is set by the mantissa; excess dynamic range beyond the minimum needed
for that SQNR is set by the exponent range (``e_max - 1`` octaves).

Per §IV-B, converters are dimensioned to robustly process *a uniform input
scaled to its narrowest valid bounds* (twice the minimum normal value): the
excess DR manifests as a 2^-(e_max-1) amplitude reduction for the
conventional CIM, while the GR-MAC renormalizes it away.  Weights are
FP4_E2M1 max-entropy throughout (information-optimal first-order
approximation of empirical weights).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax

from .adc import required_enob
from .distributions import uniform
from .energy import CimDesign, EnergyBreakdown, TechParams, energy_per_op_fj
from .formats import FP4_E2M1, FPFormat, IntFormat

__all__ = ["DsePoint", "explore", "explore_sites", "spec_of_format",
           "GAIN_RANGE_LIMIT_BITS"]

# Conservative C-2C linearity limit on the coupling-ladder span (§III-D1).
GAIN_RANGE_LIMIT_BITS = 6


@dataclasses.dataclass
class DsePoint:
    fmt_x: FPFormat | IntFormat
    dr_db: float
    sqnr_db: float
    conv: Optional[EnergyBreakdown]      # None when outside conventional reach
    gr: Optional[EnergyBreakdown]        # best GR granularity (None if infeasible)
    gr_arch: Optional[str]
    enob_conv: float
    enob_gr: float


def spec_of_format(fmt: FPFormat | IntFormat) -> tuple[float, float]:
    """(DR_dB, SQNR_dB) coordinates of a format in the design space.

    DR counts total resolvable bits: information bits (mantissa incl. the
    implicit leading one) plus excess-range octaves.  SQNR follows the
    6.02·N_M + 10.79 dB floating-point formula (stored mantissa bits).
    """
    if isinstance(fmt, IntFormat):
        bits = fmt.bits
        return 6.02 * bits, 6.02 * (bits - 1) + 1.76
    dr_bits = (fmt.n_man + 2) + (fmt.e_max - 1)  # sign+implicit+stored + range
    return 6.02 * dr_bits, 6.02 * fmt.n_man + 10.79


def _narrowest_uniform(fmt: FPFormat | IntFormat):
    """Uniform input at the narrowest valid bounds of the format (§IV-B)."""
    if isinstance(fmt, IntFormat):
        return uniform(1.0)
    return uniform(min(1.0, 2.0 * fmt.min_normal))


def evaluate_point(
    key: jax.Array,
    fmt_x: FPFormat | IntFormat,
    fmt_w: FPFormat = FP4_E2M1,
    n_r: int = 32,
    n_c: int = 32,
    p: TechParams = TechParams(),
    n_cols: int = 1 << 13,
) -> DsePoint:
    dist = _narrowest_uniform(fmt_x)
    dr_db, sqnr_db = spec_of_format(fmt_x)

    res_conv = required_enob(key, "conv", dist, fmt_x, n_r=n_r, fmt_w=fmt_w, n_cols=n_cols)
    conv = energy_per_op_fj(
        CimDesign("conv", fmt_x, fmt_w, res_conv.enob, n_r, n_c), p
    )

    best = None
    best_arch = None
    best_enob = float("nan")
    if isinstance(fmt_x, IntFormat):
        cand = ["gr_int"]
    else:
        cand = ["gr_row", "gr_unit"]
    for arch in cand:
        solver_arch = "gr_unit" if arch == "gr_int" else arch
        res = required_enob(key, solver_arch, dist, fmt_x, n_r=n_r, fmt_w=fmt_w, n_cols=n_cols)
        d = CimDesign(arch, fmt_x, fmt_w, res.enob, n_r, n_c)
        if d.gain_range_bits > GAIN_RANGE_LIMIT_BITS:
            continue  # outside the coupling ladder's linear span
        e = energy_per_op_fj(d, p)
        if best is None or e.total < best.total:
            best, best_arch, best_enob = e, arch, res.enob

    return DsePoint(
        fmt_x=fmt_x,
        dr_db=dr_db,
        sqnr_db=sqnr_db,
        conv=conv,
        gr=best,
        gr_arch=best_arch,
        enob_conv=res_conv.enob,
        enob_gr=best_enob,
    )


def explore_sites(
    cim,
    ledger,
    *,
    granularities=("row", "unit", "conv"),
    seed: int = 0,
    n_cols: int = 1 << 11,
) -> dict:
    """Per-site design sweep over a traced ``core.costs.CostLedger``.

    This is the design space the paper's framework implies but never
    sweeps: each matmul *site* (attention projections, MLP, MoE router /
    experts, SSM/RG-LRU heads, LM head — see ``core.cim_config.SITES``)
    can run its own normalization granularity, and the per-site op counts
    from the trace weight the choice. For every analog site in ``ledger``
    the candidate granularities are priced at that site's formats / n_r
    (infeasible candidates — coupling ladder beyond
    ``GAIN_RANGE_LIMIT_BITS`` — are skipped) and the cheapest wins.

    Returns ``{"sites": {site: {...}}, "config": CIMConfig, "pj": float,
    "base_pj": float}`` where ``config`` is ``cim`` with
    ``site_overrides`` set to the winning mixed deployment and the pj
    figures price the whole ledger under the swept vs the base designs.
    """
    from .cim_config import SiteDesign
    from .costs import _GRAN_ARCH, design_energy_fj

    sites: dict = {}
    best_cfg = cim
    pj_best = 0.0
    pj_base = 0.0
    for site in ledger.sites():
        ops = 2 * ledger.macs(site=site, analog_only=True)
        base = cim.for_site(site)
        if ops == 0 or not base.enabled:
            sites[site] = {"mode": "off", "ops": 2 * ledger.macs(site=site)}
            continue
        base_pt = design_energy_fj(base.granularity, base.fmt_x, base.fmt_w,
                                   base.n_r, n_cols=n_cols, seed=seed)
        pj_base += ops * base_pt["fj_per_op"] * 1e-3
        best = None
        for g in granularities:
            d = CimDesign(_GRAN_ARCH[g], base.fmt_x, base.fmt_w, 0.0,
                          base.n_r)
            if d.gain_range_bits > GAIN_RANGE_LIMIT_BITS:
                continue  # outside the coupling ladder's linear span
            pt = design_energy_fj(g, base.fmt_x, base.fmt_w, base.n_r,
                                  n_cols=n_cols, seed=seed)
            if best is None or pt["fj_per_op"] < best[1]["fj_per_op"]:
                best = (g, pt)
        if best is None:
            # every candidate outside the coupling ladder (possible when
            # the caller restricts granularities and the formats are wide)
            # -> the site keeps its base design
            pj_best += ops * base_pt["fj_per_op"] * 1e-3
            sites[site] = {
                "granularity": base.granularity,
                "fj_per_op": base_pt["fj_per_op"],
                "enob": base_pt["enob"], "ops": ops,
                "pj": ops * base_pt["fj_per_op"] * 1e-3,
                "base_granularity": base.granularity,
                "base_fj_per_op": base_pt["fj_per_op"],
                "infeasible_candidates": True,
            }
            continue
        g, pt = best
        pj_best += ops * pt["fj_per_op"] * 1e-3
        sites[site] = {
            "granularity": g, "fj_per_op": pt["fj_per_op"],
            "enob": pt["enob"], "ops": ops,
            "pj": ops * pt["fj_per_op"] * 1e-3,
            "base_granularity": base.granularity,
            "base_fj_per_op": base_pt["fj_per_op"],
        }
        if g != base.granularity:
            best_cfg = best_cfg.override_site(site, SiteDesign(granularity=g))
    return {"sites": sites, "config": best_cfg, "pj": pj_best,
            "base_pj": pj_base}


def explore(
    key: jax.Array,
    n_exps=(0, 1, 2, 3, 4),
    n_mans=(1, 2, 3, 4, 5, 6),
    fmt_w: FPFormat = FP4_E2M1,
    n_r: int = 32,
    n_c: int = 32,
    p: TechParams = TechParams(),
    n_cols: int = 1 << 13,
) -> list[DsePoint]:
    """Sweep the (n_exp × n_man) grid.  n_exp == 0 denotes an INT format of
    equivalent precision (sign + implicit + stored mantissa bits)."""
    pts = []
    for ne in n_exps:
        for nm in n_mans:
            fmt = IntFormat(nm + 2) if ne == 0 else FPFormat(ne, nm)
            key, sub = jax.random.split(key)
            pts.append(evaluate_point(sub, fmt, fmt_w, n_r, n_c, p, n_cols))
    return pts
