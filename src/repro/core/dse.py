"""Design-space exploration: the format grid (Fig. 12) and the per-site
(format × n_r × granularity) Pareto explorer.

Two layers live here:

1. **The paper's Fig. 12 grid** (``explore`` / ``evaluate_point``): each
   design point is an input format (``n_exp``, ``n_man``). Precision (SQNR)
   is set by the mantissa; excess dynamic range beyond the minimum needed
   for that SQNR is set by the exponent range (``e_max - 1`` octaves). Per
   §IV-B, converters are dimensioned to robustly process *a uniform input
   scaled to its narrowest valid bounds* (twice the minimum normal value):
   the excess DR manifests as a 2^-(e_max-1) amplitude reduction for the
   conventional CIM, while the GR-MAC renormalizes it away. Weights are
   FP4_E2M1 max-entropy throughout.

2. **The per-site Pareto explorer** (``explore_pareto`` — the design space
   the paper implies but never sweeps). Because the GR-MAC makes ADC
   resolution invariant to input dynamic range, the interesting question
   per matmul *site* (``core.cim_config.SITES``) becomes which input
   format and row-parallelism that site actually needs at a given accuracy
   standard. The swept axes per site are:

   * ``fmt_x``   — the FP/INT ladder (``FORMAT_LADDER``; INT entries price
     through the ``gr_int`` energy arch at GR granularities);
   * ``n_r``     — array depth (``N_R_LADDER``): deeper arrays amortize the
     per-column ADC over more MACs but accumulate more rows, which raises
     the renormalization-scale statistics and with them the required ENOB
     — the sweep resolves that trade per candidate, nothing is assumed;
   * ``granularity`` — row / unit / conv normalization domain (§III-C).

   **Budget semantics** (``SiteBudget``): a candidate is admissible when
   its *format* SQNR — ``spec_of_format``'s 6.02·N_M + 10.79 dB (FP) or
   6.02·(bits-1) + 1.76 dB (INT) — meets the site's floor. The default is
   the paper's 35 dB accuracy standard (``PAPER_SQNR_STANDARD_DB``). The
   required-ENOB solve then holds ADC noise ≥ 6 dB under that format's
   quantization noise (``core.adc``), so the delivered output SQNR tracks
   the format SQNR the budget is written against. A budget may also be
   stated as a minimum ENOB (converted through the 6.02·N + 1.76 dB line);
   when both fields are set the stricter floor wins. A site with NO
   admissible candidate under an active budget falls back to ``"off"``
   (digital) with a ``UserWarning`` — an analog site that cannot meet the
   accuracy standard is not deployed.

   **GAIN_RANGE_LIMIT_BITS × the n_r sweep**: the C-2C coupling-ladder
   span limit (§III-D1) depends only on the formats' exponent ranges, not
   on ``n_r`` — so it prunes the same (format, granularity) combinations
   at every array depth (wide-exponent formats such as FP8_E4M3 can enter
   the space only through ``conv``), and the sweep skips those combos
   before paying any Monte-Carlo solve. The solves that do run are
   memoized on the full candidate tuple (``core.adc.solve_required_enob``
   via ``core.costs.design_energy_fj``), which is what keeps the
   combinatorial sweep — |formats| × |n_r| × |granularities| × sites ×
   phases — tractable: distinct solves are bounded by the candidate grid,
   not by the number of sites or phases that share it.

   Results per ledger: a per-site energy/accuracy **Pareto front**
   (``pareto_front`` — fJ/Op weighted by the site's traced op count vs
   format SQNR), the chosen (cheapest admissible) design per site emitted
   as a ready-to-apply ``{site: SiteDesign}`` mapping
   (``CIMConfig.with_site_overrides``), and a deployment-level front
   (``deployment_front``: total pJ vs the weakest-site SQNR floor).

``explore_sites`` (granularity-only at the base formats) is the degenerate
sweep: ``explore_pareto(formats=(base.fmt_x,), n_r_set=(base.n_r,),
budget=None)`` reproduces it (regression-tested).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax

from .adc import required_enob
from .cim_config import SiteDesign
from .costs import design_arch, design_energy_fj
from .distributions import uniform
from .energy import CimDesign, EnergyBreakdown, TechParams, energy_per_op_fj
from .formats import (FP4_E2M1, FP6_E2M3, FP6_E3M2, FP8_E4M3, FPFormat,
                      IntFormat)

__all__ = ["DsePoint", "explore", "explore_sites", "spec_of_format",
           "GAIN_RANGE_LIMIT_BITS", "FORMAT_LADDER", "N_R_LADDER",
           "GRANULARITIES", "PAPER_SQNR_STANDARD_DB", "SiteBudget",
           "SiteCandidate", "pareto_front", "sweep_site",
           "deployment_front", "explore_pareto"]

# Conservative C-2C linearity limit on the coupling-ladder span (§III-D1).
GAIN_RANGE_LIMIT_BITS = 6

# The FP/INT candidate ladder for the per-site sweep: the named formats
# plus the wider-mantissa points needed to clear the 35 dB standard
# (6.02·N_M + 10.79 dB ≥ 35 needs N_M ≥ 5 for FP; 6.02·(bits-1) + 1.76 ≥ 35
# needs INT7+), and the INT column of the Fig. 12 grid.
FORMAT_LADDER: Tuple[Union[FPFormat, IntFormat], ...] = (
    IntFormat(4), IntFormat(6), IntFormat(8),
    FP4_E2M1, FP6_E2M3, FP6_E3M2, FP8_E4M3,
    FPFormat(2, 4), FPFormat(3, 4), FPFormat(2, 5), FPFormat(3, 5),
)

# Small power-of-two array depths around the paper's N_R = 32 reference.
N_R_LADDER: Tuple[int, ...] = (16, 32, 64, 128)

GRANULARITIES: Tuple[str, ...] = ("row", "unit", "conv")

# The paper's accuracy standard (§IV): the iso-accuracy column Fig. 12's
# energy comparison is read at.
PAPER_SQNR_STANDARD_DB = 35.0


@dataclasses.dataclass
class DsePoint:
    fmt_x: FPFormat | IntFormat
    dr_db: float
    sqnr_db: float
    conv: Optional[EnergyBreakdown]      # None when outside conventional reach
    gr: Optional[EnergyBreakdown]        # best GR granularity (None if infeasible)
    gr_arch: Optional[str]
    enob_conv: float
    enob_gr: float


def spec_of_format(fmt: FPFormat | IntFormat) -> tuple[float, float]:
    """(DR_dB, SQNR_dB) coordinates of a format in the design space.

    DR counts total resolvable bits: information bits (mantissa incl. the
    implicit leading one) plus excess-range octaves.  SQNR follows the
    6.02·N_M + 10.79 dB floating-point formula (stored mantissa bits).
    """
    if isinstance(fmt, IntFormat):
        bits = fmt.bits
        return 6.02 * bits, 6.02 * (bits - 1) + 1.76
    dr_bits = (fmt.n_man + 2) + (fmt.e_max - 1)  # sign+implicit+stored + range
    return 6.02 * dr_bits, 6.02 * fmt.n_man + 10.79


def _narrowest_uniform(fmt: FPFormat | IntFormat):
    """Uniform input at the narrowest valid bounds of the format (§IV-B)."""
    if isinstance(fmt, IntFormat):
        return uniform(1.0)
    return uniform(min(1.0, 2.0 * fmt.min_normal))


def evaluate_point(
    key: jax.Array,
    fmt_x: FPFormat | IntFormat,
    fmt_w: FPFormat = FP4_E2M1,
    n_r: int = 32,
    n_c: int = 32,
    p: TechParams = TechParams(),
    n_cols: int = 1 << 13,
) -> DsePoint:
    dist = _narrowest_uniform(fmt_x)
    dr_db, sqnr_db = spec_of_format(fmt_x)

    res_conv = required_enob(key, "conv", dist, fmt_x, n_r=n_r, fmt_w=fmt_w, n_cols=n_cols)
    conv = energy_per_op_fj(
        CimDesign("conv", fmt_x, fmt_w, res_conv.enob, n_r, n_c), p
    )

    best = None
    best_arch = None
    best_enob = float("nan")
    if isinstance(fmt_x, IntFormat):
        cand = ["gr_int"]
    else:
        cand = ["gr_row", "gr_unit"]
    for arch in cand:
        solver_arch = "gr_unit" if arch == "gr_int" else arch
        res = required_enob(key, solver_arch, dist, fmt_x, n_r=n_r, fmt_w=fmt_w, n_cols=n_cols)
        d = CimDesign(arch, fmt_x, fmt_w, res.enob, n_r, n_c)
        if d.gain_range_bits > GAIN_RANGE_LIMIT_BITS:
            continue  # outside the coupling ladder's linear span
        e = energy_per_op_fj(d, p)
        if best is None or e.total < best.total:
            best, best_arch, best_enob = e, arch, res.enob

    return DsePoint(
        fmt_x=fmt_x,
        dr_db=dr_db,
        sqnr_db=sqnr_db,
        conv=conv,
        gr=best,
        gr_arch=best_arch,
        enob_conv=res_conv.enob,
        enob_gr=best_enob,
    )


# ------------------------------------------------------- per-site sweep
@dataclasses.dataclass(frozen=True)
class SiteBudget:
    """Per-site accuracy floor. ``min_sqnr_db`` is written against the
    candidate *format's* SQNR (``spec_of_format``); ``min_enob`` states the
    same floor in effective bits (6.02·N + 1.76 dB). When both are set the
    stricter one applies; a budget with neither admits every candidate."""

    min_sqnr_db: Optional[float] = PAPER_SQNR_STANDARD_DB
    min_enob: Optional[float] = None

    def floor_db(self) -> Optional[float]:
        floors = []
        if self.min_sqnr_db is not None:
            floors.append(self.min_sqnr_db)
        if self.min_enob is not None:
            floors.append(6.02 * self.min_enob + 1.76)
        return max(floors) if floors else None

    def admits(self, sqnr_db: float) -> bool:
        floor = self.floor_db()
        return floor is None or sqnr_db >= floor


@dataclasses.dataclass(frozen=True)
class SiteCandidate:
    """One admissible point of a site's sweep: a (format, n_r, granularity)
    design with its solved ADC requirement and op-count-weighted energy."""

    fmt_x: Union[FPFormat, IntFormat]
    n_r: int
    granularity: str
    arch: str                 # energy-model arch (gr_row/gr_unit/gr_int/conv)
    fj_per_op: float
    enob: float
    sqnr_db: float            # format SQNR: the accuracy axis
    dr_db: float
    ops: int                  # ledger Ops at this site (weights pj)

    @property
    def key(self) -> str:
        """Stable candidate id used in records and rendered tables."""
        return f"{self.fmt_x.name}/n{self.n_r}/{self.granularity}"

    @property
    def pj(self) -> float:
        return self.ops * self.fj_per_op * 1e-3

    def design(self) -> SiteDesign:
        """The ready-to-apply override for this candidate."""
        return SiteDesign(granularity=self.granularity, fmt_x=self.fmt_x,
                          n_r=self.n_r)

    def as_dict(self) -> dict:
        return {
            "fmt_x": self.fmt_x.name, "n_r": self.n_r,
            "granularity": self.granularity, "arch": self.arch,
            "fj_per_op": self.fj_per_op, "enob": self.enob,
            "sqnr_db": self.sqnr_db, "dr_db": self.dr_db,
            "pj": self.pj,
        }


def pareto_front(points: Iterable, *, energy=lambda c: c.fj_per_op,
                 accuracy=lambda c: c.sqnr_db) -> list:
    """Non-dominated subset under (minimize ``energy``, maximize
    ``accuracy``), sorted by energy ascending. ``a`` dominates ``b`` when
    ``energy(a) <= energy(b)`` and ``accuracy(a) >= accuracy(b)`` with at
    least one strict; ties on both axes keep the first point seen (the
    sweep order is deterministic, so records are stable)."""
    front: list = []
    for p in sorted(points, key=lambda c: (energy(c), -accuracy(c))):
        if not front or accuracy(p) > accuracy(front[-1]):
            front.append(p)
    return front


def sweep_site(
    base,
    ops: int,
    *,
    formats: Sequence = FORMAT_LADDER,
    n_r_set: Sequence[int] = N_R_LADDER,
    granularities: Sequence[str] = GRANULARITIES,
    budget: Optional[SiteBudget] = SiteBudget(),
    seed: int = 0,
    n_cols: int = 1 << 11,
) -> dict:
    """Sweep one site's candidate grid against its accuracy budget.

    ``base`` is the site's resolved ``CIMConfig`` (supplies ``fmt_w``);
    ``ops`` the ledger op count weighting the energy axis. Returns
    ``{"candidates", "front", "chosen", "n_pruned"}`` where ``chosen`` is
    the cheapest front point (None when nothing is admissible) and
    ``n_pruned`` counts budget- or gain-range-rejected combos."""
    candidates: List[SiteCandidate] = []
    n_pruned = 0
    seen_archs = set()
    for fmt in formats:
        dr_db, sqnr_db = spec_of_format(fmt)
        if budget is not None and not budget.admits(sqnr_db):
            n_pruned += len(n_r_set) * len(granularities)
            continue
        for g in granularities:
            arch = design_arch(g, fmt)
            # gain-range feasibility is n_r-invariant: check once per
            # (format, granularity) with a dummy depth
            probe = CimDesign(arch, fmt, base.fmt_w, 0.0, n_r_set[0])
            if probe.gain_range_bits > GAIN_RANGE_LIMIT_BITS:
                n_pruned += len(n_r_set)
                continue
            if (fmt, arch) in seen_archs:
                continue  # e.g. INT row/unit both price as gr_int
            seen_archs.add((fmt, arch))
            for n_r in n_r_set:
                pt = design_energy_fj(g, fmt, base.fmt_w, int(n_r),
                                      n_cols=n_cols, seed=seed)
                candidates.append(SiteCandidate(
                    fmt_x=fmt, n_r=int(n_r), granularity=g, arch=pt["arch"],
                    fj_per_op=pt["fj_per_op"], enob=pt["enob"],
                    sqnr_db=sqnr_db, dr_db=dr_db, ops=ops))
    front = pareto_front(candidates)
    return {
        "candidates": candidates,
        "front": front,
        "chosen": front[0] if front else None,
        "n_pruned": n_pruned,
    }


def deployment_front(site_results: Dict[str, dict]) -> List[dict]:
    """Arch×phase-level energy/accuracy front over the swept sites.

    The deployment's accuracy is its weakest site (the minimum per-site
    format SQNR); its energy is the ledger-weighted total. For every
    accuracy floor available in the candidate sets, each site takes its
    cheapest candidate meeting that floor; levels where some site has no
    such candidate are infeasible and dropped. The Pareto filter over the
    resulting (total pJ, floor) points is the front ``launch/summary.py
    --energy`` renders per arch × phase."""
    swept = {s: r for s, r in site_results.items() if r["candidates"]}
    if not swept:
        return []
    levels = sorted({c.sqnr_db for r in swept.values()
                     for c in r["candidates"]})
    points = []
    for level in levels:
        total_pj = 0.0
        choices = {}
        for site, r in swept.items():
            ok = [c for c in r["candidates"] if c.sqnr_db >= level]
            if not ok:
                choices = None
                break
            pick = min(ok, key=lambda c: (c.fj_per_op, -c.sqnr_db))
            total_pj += pick.pj
            choices[site] = pick.key
        if choices is None:
            continue
        points.append({"sqnr_db": level, "pj": total_pj,
                       "choices": choices})
    return pareto_front(points, energy=lambda p: p["pj"],
                        accuracy=lambda p: p["sqnr_db"])


def explore_pareto(
    cim,
    ledger,
    *,
    formats: Sequence = FORMAT_LADDER,
    n_r_set: Sequence[int] = N_R_LADDER,
    granularities: Sequence[str] = GRANULARITIES,
    budget: Union[SiteBudget, Dict[str, Optional[SiteBudget]], None]
        = SiteBudget(),
    seed: int = 0,
    n_cols: int = 1 << 11,
) -> dict:
    """Per-site (format × n_r × granularity) Pareto DSE over a traced
    ``core.costs.CostLedger`` under per-site accuracy budgets.

    For every analog site in ``ledger`` the full candidate grid is priced
    (budget- and gain-range-pruned, Monte-Carlo solves memoized — see the
    module docstring), the energy/accuracy Pareto front is kept, and the
    cheapest admissible point is *chosen*. ``budget`` is one
    ``SiteBudget`` for all sites, a ``{site: SiteBudget | None}`` mapping
    (missing sites get the default), or None (no accuracy constraint —
    the degenerate sweep).

    Fallbacks: a site with no admissible candidate under an **active**
    budget resolves to ``"off"`` with a ``UserWarning``; with no active
    budget (the explore_sites-compatible mode) it keeps its base design.

    Returns ``{"sites", "front", "site_overrides", "config", "pj",
    "base_pj"}``: ``site_overrides`` is the ready-to-apply ``{site: "off"
    | SiteDesign}`` chosen frontier, ``config`` is ``cim`` with it applied
    (``CIMConfig.with_site_overrides``), ``front`` the deployment-level
    front (``deployment_front``), and the pj figures price the whole
    ledger under the chosen vs the base designs."""
    default_budget = budget if isinstance(budget, (SiteBudget, type(None))) \
        else SiteBudget()
    budget_map = budget if isinstance(budget, dict) else {}

    sites: Dict[str, dict] = {}
    overrides: Dict[str, Union[str, SiteDesign]] = {}
    swept: Dict[str, dict] = {}
    pj_chosen = 0.0
    pj_base = 0.0
    for site in ledger.sites():
        ops = 2 * ledger.macs(site=site, analog_only=True)
        base = cim.for_site(site)
        if ops == 0 or not base.enabled:
            sites[site] = {"mode": "off", "ops": 2 * ledger.macs(site=site)}
            continue
        site_budget = budget_map.get(site, default_budget)
        base_pt = design_energy_fj(base.granularity, base.fmt_x, base.fmt_w,
                                   base.n_r, n_cols=n_cols, seed=seed)
        pj_base += ops * base_pt["fj_per_op"] * 1e-3
        res = sweep_site(base, ops, formats=formats, n_r_set=n_r_set,
                         granularities=granularities, budget=site_budget,
                         seed=seed, n_cols=n_cols)
        info = {
            "ops": ops,
            "budget_sqnr_db": site_budget.floor_db()
            if site_budget is not None else None,
            "base": {"granularity": base.granularity,
                     "fmt_x": base.fmt_x.name, "n_r": base.n_r,
                     "fj_per_op": base_pt["fj_per_op"]},
            "front": [c.as_dict() for c in res["front"]],
            "n_candidates": len(res["candidates"]),
            "n_pruned": res["n_pruned"],
        }
        chosen = res["chosen"]
        if chosen is None:
            if site_budget is not None and site_budget.floor_db() is not None:
                warnings.warn(
                    f"site {site!r}: no (format, n_r, granularity) candidate "
                    f"meets the {site_budget.floor_db():.1f} dB accuracy "
                    "budget within the coupling-ladder span — deploying the "
                    "site digital (\"off\")")
                overrides[site] = "off"
                info["chosen"] = "off"
            else:
                # no active budget: keep the base design (the
                # explore_sites-compatible degenerate fallback)
                pj_chosen += ops * base_pt["fj_per_op"] * 1e-3
                info["chosen"] = "base"
            sites[site] = info
            continue
        swept[site] = res
        pj_chosen += chosen.pj
        overrides[site] = chosen.design()
        info["chosen"] = chosen.as_dict()
        sites[site] = info
    return {
        "sites": sites,
        "front": deployment_front(swept),
        "site_overrides": overrides,
        "config": cim.with_site_overrides(overrides),
        "pj": pj_chosen,
        "base_pj": pj_base,
    }


def explore_sites(
    cim,
    ledger,
    *,
    granularities=("row", "unit", "conv"),
    seed: int = 0,
    n_cols: int = 1 << 11,
) -> dict:
    """Granularity-only per-site sweep at the base formats — the degenerate
    case of ``explore_pareto`` (kept as the cheap entry point and the
    regression anchor: ``explore_pareto(formats=(base.fmt_x,),
    n_r_set=(base.n_r,), budget=None)`` reproduces these results).

    Returns ``{"sites": {site: {...}}, "config": CIMConfig, "pj": float,
    "base_pj": float}`` where ``config`` is ``cim`` with
    ``site_overrides`` set to the winning mixed deployment and the pj
    figures price the whole ledger under the swept vs the base designs.
    """
    sites: dict = {}
    best_cfg = cim
    pj_best = 0.0
    pj_base = 0.0
    for site in ledger.sites():
        ops = 2 * ledger.macs(site=site, analog_only=True)
        base = cim.for_site(site)
        if ops == 0 or not base.enabled:
            sites[site] = {"mode": "off", "ops": 2 * ledger.macs(site=site)}
            continue
        base_pt = design_energy_fj(base.granularity, base.fmt_x, base.fmt_w,
                                   base.n_r, n_cols=n_cols, seed=seed)
        pj_base += ops * base_pt["fj_per_op"] * 1e-3
        best = None
        for g in granularities:
            d = CimDesign(design_arch(g, base.fmt_x), base.fmt_x,
                          base.fmt_w, 0.0, base.n_r)
            if d.gain_range_bits > GAIN_RANGE_LIMIT_BITS:
                continue  # outside the coupling ladder's linear span
            pt = design_energy_fj(g, base.fmt_x, base.fmt_w, base.n_r,
                                  n_cols=n_cols, seed=seed)
            if best is None or pt["fj_per_op"] < best[1]["fj_per_op"]:
                best = (g, pt)
        if best is None:
            # every candidate outside the coupling ladder (possible when
            # the caller restricts granularities and the formats are wide)
            # -> the site keeps its base design
            pj_best += ops * base_pt["fj_per_op"] * 1e-3
            sites[site] = {
                "granularity": base.granularity,
                "fj_per_op": base_pt["fj_per_op"],
                "enob": base_pt["enob"], "ops": ops,
                "pj": ops * base_pt["fj_per_op"] * 1e-3,
                "base_granularity": base.granularity,
                "base_fj_per_op": base_pt["fj_per_op"],
                "infeasible_candidates": True,
            }
            continue
        g, pt = best
        pj_best += ops * pt["fj_per_op"] * 1e-3
        sites[site] = {
            "granularity": g, "fj_per_op": pt["fj_per_op"],
            "enob": pt["enob"], "ops": ops,
            "pj": ops * pt["fj_per_op"] * 1e-3,
            "base_granularity": base.granularity,
            "base_fj_per_op": base_pt["fj_per_op"],
        }
        if g != base.granularity:
            best_cfg = best_cfg.override_site(site, SiteDesign(granularity=g))
    return {"sites": sites, "config": best_cfg, "pj": pj_best,
            "base_pj": pj_base}


def explore(
    key: jax.Array,
    n_exps=(0, 1, 2, 3, 4),
    n_mans=(1, 2, 3, 4, 5, 6),
    fmt_w: FPFormat = FP4_E2M1,
    n_r: int = 32,
    n_c: int = 32,
    p: TechParams = TechParams(),
    n_cols: int = 1 << 13,
) -> list[DsePoint]:
    """Sweep the (n_exp × n_man) grid.  n_exp == 0 denotes an INT format of
    equivalent precision (sign + implicit + stored mantissa bits)."""
    pts = []
    for ne in n_exps:
        for nm in n_mans:
            fmt = IntFormat(nm + 2) if ne == 0 else FPFormat(ne, nm)
            key, sub = jax.random.split(key)
            pts.append(evaluate_point(sub, fmt, fmt_w, n_r, n_c, p, n_cols))
    return pts
