"""Trace-derived CIM cost accounting: the ``CostLedger`` subsystem.

Why a ledger instead of a formula
---------------------------------
The paper's bottom line is an *energy* claim (the GR-MAC holds ADC energy
flat while gaining dynamic range), so the end-to-end numbers must count the
MACs the models actually execute. The previous ``energy_report`` re-derived
every architecture's structure by hand (an analytic MAC census over
``arch.blocks()``) and priced all sites at one design point — drift-prone
(any model change silently invalidated it) and blind to the differences
between prefill, decode and train, and between per-site designs.

This module replaces the census with *structural* accounting:

1. every projection matmul in the models carries a **site** label
   (``core.cim_config.SITES``) threaded through ``kernels.ops.cim_matmul``;
2. a shape-only ``jax.eval_shape`` trace of the *real* model functions —
   ``models.prefill_step`` (per bucket), ``models.decode_step``, and the
   ``models.train_loss`` grad step — runs under ``recording(ledger)``;
   every ``cim_matmul`` call (and the MoE expert stacks, see below) then
   records ``(site, M, K, N, mode, granularity, fmt_x, fmt_w, n_r)`` into
   the active ``CostLedger``. Nothing is compiled or allocated: the trace
   is abstract, parameters and caches come from ``jax.eval_shape`` of
   ``init_params`` / ``init_cache``, and traces run with
   ``scan_layers=False`` so every layer's calls are counted exactly once
   (a ``lax.scan`` body would trace — and record — once for *n* layers).
3. pricing multiplies each entry's op count by the fJ/Op of *that site's
   resolved design* (``CIMConfig.for_site``), solved by the paper's
   Monte-Carlo required-ENOB model — so mixed per-site deployments
   (``CIMConfig.site_overrides``) price correctly, and the energy numbers
   are structurally un-driftable from the models: change a projection
   width, add a block, re-route a tensor, and the ledger follows.

Accounting conventions
----------------------
* Counts are **logical** MACs. Two places diverge from physical buffer
  shapes: the MoE expert stacks record ``tokens × top_k`` rows (the routed
  assignments) rather than the fixed-capacity ``E × cap`` dispatch buffer,
  and the LM head records the true ``vocab_size`` columns rather than the
  256-aligned ``padded_vocab`` (pad columns are masked and would not be
  mapped onto an analog array). Both conventions match the retired census,
  which the cross-check test (tests/test_costs.py) pins exactly.
* Sites whose resolved design is ``mode="off"`` are still recorded (they
  are real matmuls) but price as digital — zero *analog* energy. The
  report keeps digital and analog op counts separate.
* The STE backward of ``cim_matmul`` is an exact digital matmul by
  construction, so a train-step trace records the *forward* analog ops
  only: that is what hits the array; the backward is digital by design.

Entry points
------------
``trace_decode`` / ``trace_prefill`` / ``trace_train``  build ledgers;
``price_ledger``  turns a ledger into a per-site / per-token energy report;
``phase_report``  runs all three phases for one arch (what
``serving.engine.energy_report`` and ``benchmarks/e2e_energy.py`` print).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .adc import solve_required_enob
from .cim_config import CIMConfig
from .energy import CimDesign, TechParams, energy_per_op_fj
from .formats import FPFormat, IntFormat

__all__ = [
    "LedgerEntry",
    "CostLedger",
    "recording",
    "record_matmul",
    "phase_trace_spec",
    "trace_decode",
    "trace_prefill",
    "trace_train",
    "default_train_seq",
    "design_arch",
    "design_energy_fj",
    "price_ledger",
    "phase_report",
]

_GRAN_ARCH = {"row": "gr_row", "unit": "gr_unit", "conv": "conv"}


# ------------------------------------------------------------------ ledger
@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    """One distinct matmul contract: a site executing (M, K) @ (K, N)
    under a resolved CIM design. The ledger maps entries to call counts."""

    site: str
    m: int
    k: int
    n: int
    mode: str                # off | fakequant | grmac
    granularity: str         # row | unit | conv
    fmt_x: FPFormat
    fmt_w: FPFormat
    n_r: int

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n

    @property
    def analog(self) -> bool:
        """Does this contract hit the analog array at deployment?
        ``fakequant`` counts: it is the QAT stand-in for ``grmac``."""
        return self.mode != "off"

    def design_key(self) -> tuple:
        return (self.granularity, self.fmt_x, self.fmt_w, self.n_r)


class CostLedger:
    """Counts of matmul contracts executed by one traced step."""

    def __init__(self):
        self._counts: Dict[LedgerEntry, int] = {}

    def add(self, entry: LedgerEntry, count: int = 1) -> None:
        self._counts[entry] = self._counts.get(entry, 0) + count

    def merge(self, other: "CostLedger", times: int = 1) -> "CostLedger":
        for e, c in other._counts.items():
            self.add(e, c * times)
        return self

    def entries(self) -> List[Tuple[LedgerEntry, int]]:
        return sorted(self._counts.items(),
                      key=lambda ec: (ec[0].site, ec[0].m, ec[0].k, ec[0].n))

    def macs(self, site: Optional[str] = None,
             analog_only: bool = False) -> int:
        return sum(e.macs * c for e, c in self._counts.items()
                   if (site is None or e.site == site)
                   and (not analog_only or e.analog))

    def sites(self) -> List[str]:
        return sorted({e.site for e in self._counts})

    def __len__(self) -> int:
        return len(self._counts)

    def as_dict(self) -> list:
        """JSON-able dump (formats by name), sorted for stable records."""
        return [
            {"site": e.site, "m": e.m, "k": e.k, "n": e.n, "count": c,
             "mode": e.mode, "granularity": e.granularity,
             "fmt_x": e.fmt_x.name, "fmt_w": e.fmt_w.name, "n_r": e.n_r}
            for e, c in self.entries()
        ]


# ----------------------------------------------------------- record hooks
_ACTIVE: List[CostLedger] = []


@contextlib.contextmanager
def recording(ledger: CostLedger):
    """Route every ``cim_matmul`` (and explicit ``record_matmul``) executed
    inside the block into ``ledger``. Shapes are read at Python level, so
    this works identically under ``jax.eval_shape``."""
    _ACTIVE.append(ledger)
    try:
        yield ledger
    finally:
        _ACTIVE.pop()


def record_matmul(site: Optional[str], m: int, k: int, n: int,
                  cfg: Optional[CIMConfig]) -> None:
    """Record one (M, K) @ (K, N) contract at ``site`` under the *resolved*
    design ``cfg`` (None = plain digital matmul). No-op unless a
    ``recording`` context is active — the hot path pays one list check."""
    if not _ACTIVE:
        return
    if cfg is None:
        cfg = CIMConfig(mode="off")
    _ACTIVE[-1].add(LedgerEntry(
        site=site or "unsited", m=int(m), k=int(k), n=int(n),
        mode=cfg.mode, granularity=cfg.granularity,
        fmt_x=cfg.fmt_x, fmt_w=cfg.fmt_w, n_r=cfg.n_r))


# ------------------------------------------------------------------ traces
def _trace_arch(arch):
    # scan_layers=False: cost accounting (like jax cost_analysis) must see
    # every layer's calls, not one scan body per super-block stack.
    # remat=False: jax.checkpoint memoizes tracing per abstract signature,
    # so a rematted layer stack would fire the Python-level record hook
    # once for N identical layers (and the trace allocates nothing anyway).
    return arch.replace(scan_layers=False, remat=False)


def _abstract_params(arch):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    from repro.models import init_params  # lazy: models import kernels.ops
    return jax.eval_shape(lambda k: init_params(k, arch), key)


def _abstract_cache(arch, batch: int, ctx: int):
    from repro.models import init_cache
    return jax.eval_shape(
        lambda: init_cache(arch, batch, ctx, jnp.float32))


def _token_struct(arch, batch: int, seq: int):
    if arch.input_mode == "tokens":
        return jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq, arch.d_model), jnp.float32)


def phase_trace_spec(arch, phase: str, *, batch: int = 1,
                     ctx: Optional[int] = None, bucket: int = 128,
                     seq_len: Optional[int] = None) -> tuple:
    """The exact (callable, abstract args) pair a phase trace runs.

    Single source of the traced functions shared by the ledger builders
    below and by the jaxpr ledger audit (``repro.analysis.jaxpr_audit``):
    the audit must walk the *same* closed jaxpr whose Python trace filled
    the ``CostLedger``, or the completeness proof would be about a
    different computation. ``arch`` is normalized through ``_trace_arch``
    (scan_layers/remat off) exactly like the ledger traces.
    """
    arch = _trace_arch(arch)
    if phase == "decode":
        from repro.models import decode_step
        params = _abstract_params(arch)
        cache = _abstract_cache(arch, batch, ctx or 128)
        idx = jax.ShapeDtypeStruct((batch,), jnp.int32)
        fn = lambda p, t, c, i: decode_step(p, t, arch, c, i)  # noqa: E731
        return fn, (params, _token_struct(arch, batch, 1), cache, idx)
    if phase == "prefill":
        from repro.models import prefill_step
        ctx = ctx or max(2 * bucket, 128)
        params = _abstract_params(arch)
        cache = _abstract_cache(arch, batch, ctx)
        idx = jax.ShapeDtypeStruct((batch,), jnp.int32)
        lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
        fn = lambda p, t, c, i, l: prefill_step(p, t, arch, c, i, l)  # noqa: E731
        return fn, (params, _token_struct(arch, batch, bucket), cache,
                    idx, lens)
    if phase == "train":
        from repro.models import train_loss
        if seq_len is None:
            seq_len = default_train_seq(arch)
        labels = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        params = _abstract_params(arch)

        def step(p, inputs, lbl):
            (total, _), grads = jax.value_and_grad(
                lambda pp: train_loss(pp, {"inputs": inputs, "labels": lbl},
                                      arch), has_aux=True)(p)
            return total, grads

        return step, (params, _token_struct(arch, batch, seq_len), labels)
    raise ValueError(f"unknown phase {phase!r}")


def trace_decode(arch, batch: int = 1, ctx: int = 128) -> CostLedger:
    """Ledger of ONE decode step over ``batch`` lanes (→ ``batch`` tokens)."""
    fn, args = phase_trace_spec(arch, "decode", batch=batch, ctx=ctx)
    ledger = CostLedger()
    with recording(ledger):
        jax.eval_shape(fn, *args)
    return ledger


def trace_prefill(arch, bucket: int = 128, batch: int = 1,
                  ctx: Optional[int] = None) -> CostLedger:
    """Ledger of one bucketed prefill dispatch of ``bucket`` tokens per
    lane (→ ``batch * bucket`` tokens)."""
    fn, args = phase_trace_spec(arch, "prefill", batch=batch, bucket=bucket,
                                ctx=ctx)
    ledger = CostLedger()
    with recording(ledger):
        jax.eval_shape(fn, *args)
    return ledger


def default_train_seq(arch) -> int:
    """The train-trace sequence length when the caller doesn't pin one:
    long enough to cover an SSM chunk so the scan recurrence is exercised.
    Single source of truth for every per-token normalization of a train
    ledger (``phase_report``, ``benchmarks/e2e_energy.py``) — the divisor
    must be the length the trace actually ran."""
    return max(arch.ssm_chunk, 128) if "ssm" in arch.block_pattern else 128


def trace_train(arch, batch: int = 1,
                seq_len: Optional[int] = None) -> CostLedger:
    """Ledger of one train-step *forward* (value_and_grad traced; the STE
    backward is digital, see module docstring) over ``batch × seq_len``
    tokens."""
    fn, args = phase_trace_spec(arch, "train", batch=batch, seq_len=seq_len)
    ledger = CostLedger()
    with recording(ledger):
        jax.eval_shape(fn, *args)
    return ledger


# ----------------------------------------------------------------- pricing
def design_arch(granularity: str, fmt_x) -> str:
    """Energy-model arch of a (granularity, input-format) pair: the GR
    granularities price as ``gr_row`` / ``gr_unit`` for FP inputs and as
    ``gr_int`` for INT inputs (no input exponent to range on — the gain
    ranging runs off the static *weight* exponents, §III-C3)."""
    arch = _GRAN_ARCH[granularity]
    if arch != "conv" and isinstance(fmt_x, IntFormat):
        return "gr_int"
    return arch


@functools.lru_cache(maxsize=4096)
def design_energy_fj(granularity: str, fmt_x, fmt_w, n_r: int, *,
                     n_cols: int = 1 << 11, seed: int = 0,
                     n_c: int = 32) -> dict:
    """fJ/Op of one (granularity, formats, n_r) design and of the
    conventional CIM processing the same tensors — the paper's §IV cost
    model behind both. The required-ENOB Monte-Carlo
    (``core.adc.solve_required_enob``) is memoized per design *and* per
    sampling configuration (seed, n_cols), so a changed sampling setup can
    never be served a stale solve and the combinatorial DSE sweep
    (``core.dse.explore_pareto``) pays each distinct solve once."""
    arch = design_arch(granularity, fmt_x)
    # gr_int reuses the gr_unit solver semantics: an INT input carries a
    # single exponent bin (see core.adc.required_enob docstring)
    solver = {"conv": "conv", "gr_int": "gr_unit"}.get(arch, arch)
    res = solve_required_enob(solver, fmt_x, n_r, fmt_w, n_cols, seed)
    e = energy_per_op_fj(CimDesign(arch, fmt_x, fmt_w, res.enob, n_r, n_c),
                         TechParams())
    res_c = solve_required_enob("conv", fmt_x, n_r, fmt_w, n_cols, seed)
    e_c = energy_per_op_fj(
        CimDesign("conv", fmt_x, fmt_w, res_c.enob, n_r, n_c), TechParams())
    return {
        "arch": arch,
        "fj_per_op": e.total,
        "enob": float(res.enob),
        "breakdown": e.as_dict(),
        "conv_fj_per_op": e_c.total,
        "conv_enob": float(res_c.enob),
    }


def price_ledger(ledger: CostLedger, tokens: int, *,
                 seed: int = 0, n_cols: int = 1 << 11) -> dict:
    """Price ``ledger × energy_per_op_fj(site design)`` and normalize by
    ``tokens``. Digital (mode "off") sites contribute op counts but no
    analog energy; pJ/token sums over analog sites only."""
    sites: Dict[str, dict] = {}
    pj_total = 0.0
    pj_conv = 0.0
    analog_ops = 0
    for entry, count in ledger.entries():
        ops = 2 * entry.macs * count
        s = sites.setdefault(entry.site, {
            "ops_per_token": 0.0, "analog_ops_per_token": 0.0,
            "pj_per_token": 0.0, "mode": entry.mode,
            "granularity": entry.granularity, "fmt_x": entry.fmt_x.name,
            "fmt_w": entry.fmt_w.name, "n_r": entry.n_r,
        })
        s["ops_per_token"] += ops / tokens
        if not entry.analog:
            continue
        pt = design_energy_fj(entry.granularity, entry.fmt_x, entry.fmt_w,
                              entry.n_r, n_cols=n_cols, seed=seed)
        s["analog_ops_per_token"] += ops / tokens
        s["pj_per_token"] += ops / tokens * pt["fj_per_op"] * 1e-3
        s["fj_per_op"] = pt["fj_per_op"]
        s["enob"] = pt["enob"]
        s["design"] = pt["arch"]
        analog_ops += ops
        pj_total += ops * pt["fj_per_op"] * 1e-3
        pj_conv += ops * pt["conv_fj_per_op"] * 1e-3
    return {
        "tokens": tokens,
        "macs_per_token": ledger.macs() // tokens
        if ledger.macs() % tokens == 0 else ledger.macs() / tokens,
        "ops_per_token": 2 * ledger.macs() / tokens,
        "analog_ops_per_token": analog_ops / tokens,
        "pj_per_token": pj_total / tokens,
        "conventional_pj_per_token": pj_conv / tokens,
        "fj_per_op": (pj_total / analog_ops * 1e3) if analog_ops else 0.0,
        "conventional_fj_per_op":
            (pj_conv / analog_ops * 1e3) if analog_ops else 0.0,
        "sites": sites,
    }


def phase_report(arch, *, batch: int = 1, prefill_bucket: int = 128,
                 train_seq: Optional[int] = None, seed: int = 0,
                 n_cols: int = 1 << 11) -> dict:
    """Per-phase (prefill / decode / train) energy report for one arch:
    trace the real model functions, price per site, normalize per token."""
    decode = trace_decode(arch, batch=batch)
    prefill = trace_prefill(arch, bucket=prefill_bucket, batch=batch)
    train = trace_train(arch, batch=batch, seq_len=train_seq)
    train_tokens = batch * (train_seq or default_train_seq(arch))
    return {
        "decode": price_ledger(decode, batch, seed=seed, n_cols=n_cols),
        "prefill": price_ledger(prefill, batch * prefill_bucket,
                                seed=seed, n_cols=n_cols),
        "train": price_ledger(train, train_tokens, seed=seed,
                              n_cols=n_cols),
    }
