"""Bit-faithful simulation of the conventional INT-MAC and the GR-MAC columns.

A "column" is one analog accumulation line with ``n_r`` contributing unit
cells (paper Fig. 4). All simulators take already *format-quantized* inputs
``x_q`` and weights ``w_q`` of shape ``(..., n_r)`` and return the analog
compute-line voltage ``v`` (always in [-1, 1]), the digital renormalization
``scale`` such that the reconstructed dot product is ``v * scale``, and the
final ADC-quantized output ``z_hat``.

Signal chains
-------------
Conventional INT-MAC (§III-B1):
    v = (1/n_r) Σ_i x_i w_i                    (uniform charge averaging)
    z_hat = Q_ADC(v) * n_r

GR-MAC, row normalization (§III-C2): the cell multiplies the *mantissa*
voltage by the (pre-aligned) weight and couples with C ∝ 2^{E_x,i}:
    v = Σ_i (s_i M_i w_i) 2^{E_i}  /  Σ_i 2^{E_i}
    z_hat = Q_ADC(v) * (Σ_i 2^{E_i}) * 2^{-e_max}

GR-MAC, unit normalization (§III-C1): weights are also normalized and the
coupling uses E = E_x + E_W:
    v = Σ_i (s_i M_x,i M_W,i) 2^{E_x,i + E_W,i}  /  Σ_i 2^{E_x,i + E_W,i}
    z_hat = Q_ADC(v) * (Σ_i 2^{E_x,i+E_W,i}) * 2^{-2 e_max}

Both GR variants reconstruct Σ x_i w_i exactly when the ADC is ideal; the
architectural difference is purely the *voltage-domain amplitude* presented
to the ADC, which sets the excess resolution requirement.

An optional multiplicative capacitor-mismatch model (Pelgrom, §III-E1) is
provided: each coupling capacitor 2^{E} C_lsb receives a relative error
sigma(dC/C) = K_C / sqrt(C) with C in fF.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .formats import FPFormat, decompose, pow2i

__all__ = [
    "adc_quantize",
    "MacOutput",
    "int_mac",
    "gr_mac_row",
    "gr_mac_unit",
    "n_eff",
    "mismatch_gains",
]


def adc_quantize(v: jax.Array, enob: jax.Array | float) -> jax.Array:
    """Mid-tread uniform ADC on [-1, 1] with step 2 / 2**enob.

    ``enob`` may be fractional (the paper specifies ENOB = log2(V_FS / Δ));
    we honour the implied step size exactly.
    """
    delta = 2.0 / jnp.exp2(jnp.asarray(enob, v.dtype))
    return jnp.clip(jnp.round(v / delta) * delta, -1.0, 1.0)


@dataclasses.dataclass
class MacOutput:
    v: jax.Array       # analog compute-line voltage in [-1, 1]
    scale: jax.Array   # digital renormalization factor
    z: jax.Array       # ideal dot product (no ADC), == v * scale
    z_hat: jax.Array   # ADC-quantized output, == Q(v) * scale
    n_eff: Optional[jax.Array] = None  # effective contributor count (GR only)


def int_mac(x_q: jax.Array, w_q: jax.Array, enob: jax.Array | float) -> MacOutput:
    """Conventional charge-domain INT-MAC column (uniform averaging)."""
    n_r = x_q.shape[-1]
    v = jnp.sum(x_q * w_q, axis=-1) / n_r
    scale = jnp.asarray(float(n_r), x_q.dtype)
    z = v * scale
    z_hat = adc_quantize(v, enob) * scale
    return MacOutput(v=v, scale=jnp.broadcast_to(scale, v.shape), z=z, z_hat=z_hat)


def n_eff(gains: jax.Array) -> jax.Array:
    """Effective number of contributors for weighted averaging (§III-B2).

    N_eff = (Σ g_i)^2 / Σ g_i^2  with g_i = 2^{E_i}.
    """
    s1 = jnp.sum(gains, axis=-1)
    s2 = jnp.sum(jnp.square(gains), axis=-1)
    return jnp.square(s1) / jnp.maximum(s2, 1e-30)


def mismatch_gains(
    key: jax.Array,
    e: jax.Array,
    k_c_pct_sqrt_ff: float,
    c_unit_ff: float = 1.0,
) -> jax.Array:
    """Per-cell multiplicative coupling-gain error from capacitor mismatch.

    sigma(dC/C) = K_C / sqrt(C),  C = 2^{E-1} * c_unit_ff   (coupling ladder).
    ``k_c_pct_sqrt_ff`` is in %·sqrt(fF) (paper range 0.45–0.85).
    """
    c = jnp.exp2(e.astype(jnp.float32) - 1.0) * c_unit_ff
    sigma = (k_c_pct_sqrt_ff / 100.0) / jnp.sqrt(c)
    return 1.0 + sigma * jax.random.normal(key, e.shape)


def gr_mac_row(
    x_q: jax.Array,
    w_q: jax.Array,
    fmt_x: FPFormat,
    enob: jax.Array | float,
    gain_err: Optional[jax.Array] = None,
) -> MacOutput:
    """GR-MAC with row (input-only) normalization.

    Weights arrive pre-aligned (their true values in [-1, 1]); only inputs
    are decomposed and gain-ranged by 2^{E_x}.
    """
    s, m, e = decompose(x_q, fmt_x)
    g = pow2i(e, x_q.dtype)
    if gain_err is not None:
        g = g * gain_err
    num = jnp.sum(s * m * w_q * g, axis=-1)
    den = jnp.sum(g, axis=-1)
    v = num / den
    scale = den * 2.0 ** (-fmt_x.e_max)
    z = v * scale
    z_hat = adc_quantize(v, enob) * scale
    return MacOutput(v=v, scale=scale, z=z, z_hat=z_hat, n_eff=n_eff(g))


def gr_mac_unit(
    x_q: jax.Array,
    w_q: jax.Array,
    fmt_x: FPFormat,
    fmt_w: FPFormat,
    enob: jax.Array | float,
    gain_err: Optional[jax.Array] = None,
) -> MacOutput:
    """GR-MAC with unit (input + weight) normalization."""
    sx, mx, ex = decompose(x_q, fmt_x)
    sw, mw, ew = decompose(w_q, fmt_w)
    g = pow2i(ex + ew, x_q.dtype)
    if gain_err is not None:
        g = g * gain_err
    num = jnp.sum(sx * sw * mx * mw * g, axis=-1)
    den = jnp.sum(g, axis=-1)
    v = num / den
    scale = den * 2.0 ** (-(fmt_x.e_max + fmt_w.e_max))
    z = v * scale
    z_hat = adc_quantize(v, enob) * scale
    return MacOutput(v=v, scale=scale, z=z, z_hat=z_hat, n_eff=n_eff(g))


def global_normalize(x_q: jax.Array, fmt: FPFormat, int_bits: int):
    """Block-wise FP->INT conversion (the conventional pipeline, §II-B2).

    Aligns every value in the trailing-axis block to the block maximum
    exponent (M_i << (E_max_blk - E_i)) on an ``int_bits``-wide integer
    grid. Returns (aligned integer values in [-1, 1], block scale 2^(E-e_max))
    such that x ≈ aligned * scale. Truncation of shifted-out LSBs is the
    fidelity cost the GR-MAC avoids.
    """
    _, _, e = decompose(x_q, fmt)
    e_blk = jnp.max(e, axis=-1, keepdims=True)
    scale = pow2i(e_blk - fmt.e_max, x_q.dtype)
    normalized = x_q / scale                      # in [-1, 1] by construction
    step = 2.0 ** (1 - int_bits)
    aligned = jnp.round(normalized / step) * step  # truncating INT grid
    return jnp.clip(aligned, -1.0, 1.0), scale
